//! Typed run configuration with JSON load/save (serde stand-in).
//!
//! One [`RunConfig`] describes everything a segmentation run needs:
//! dataset, oversegmentation, MRF optimization, engine selection, and
//! execution resources. The launcher assembles it from a JSON file plus
//! CLI overrides; examples and benches build it in code.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::bp::{BpConfig, BpSchedule};
use crate::dual::DualConfig;
use crate::json::{self, Value};
use crate::pmp::PmpConfig;

pub use crate::dpp::DeviceKind;

/// Which dataset generator to use (paper §4.1.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// NGCF-like porous media: homogeneous, many small neighborhoods.
    Synthetic,
    /// ALS-like geological sample: heterogeneous, dense irregular graph.
    Experimental,
}

impl DatasetKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "synthetic" => Ok(DatasetKind::Synthetic),
            "experimental" => Ok(DatasetKind::Experimental),
            _ => bail!("unknown dataset `{s}` (synthetic|experimental)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::Synthetic => "synthetic",
            DatasetKind::Experimental => "experimental",
        }
    }
}

/// Which MRF optimization engine runs the EM loop (DESIGN.md §3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Single-threaded baseline ("Serial CPU" row of Table 1).
    Serial,
    /// Coarse-parallel OpenMP analog (Alg. 1 reference).
    Reference,
    /// The paper's contribution: fine-grained DPP pipeline (Alg. 2).
    Dpp,
    /// DPP pipeline with the EM inner step on AOT XLA artifacts
    /// (the accelerator platform of Table 1).
    Xla,
    /// Max-product loopy belief propagation on DPP sweeps with
    /// residual message scheduling (DESIGN.md §6).
    Bp,
    /// Dual block-coordinate ascent (MPLP-style) with certified
    /// lower bounds and optimality gaps (DESIGN.md §12).
    Dual,
    /// Particle max-product over continuous label spaces: per-vertex
    /// particle sets, seeded random-walk proposals, min-sum message
    /// passing, select-and-prune (DESIGN.md §14).
    Pmp,
}

impl EngineKind {
    /// Accepted `--engine` values, for help text and error messages.
    pub const USAGE: &'static str =
        "serial|reference|dpp|xla|bp|dual|pmp";

    pub fn all() -> [EngineKind; 7] {
        [
            EngineKind::Serial,
            EngineKind::Reference,
            EngineKind::Dpp,
            EngineKind::Xla,
            EngineKind::Bp,
            EngineKind::Dual,
            EngineKind::Pmp,
        ]
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "serial" => Ok(EngineKind::Serial),
            "reference" => Ok(EngineKind::Reference),
            "dpp" => Ok(EngineKind::Dpp),
            "xla" => Ok(EngineKind::Xla),
            "bp" => Ok(EngineKind::Bp),
            "dual" => Ok(EngineKind::Dual),
            "pmp" => Ok(EngineKind::Pmp),
            _ => bail!("unknown engine `{s}` ({})", Self::USAGE),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Serial => "serial",
            EngineKind::Reference => "reference",
            EngineKind::Dpp => "dpp",
            EngineKind::Xla => "xla",
            EngineKind::Bp => "bp",
            EngineKind::Dual => "dual",
            EngineKind::Pmp => "pmp",
        }
    }

    /// One-line description for `dpp-pmrf engines`.
    pub fn about(&self) -> &'static str {
        match self {
            EngineKind::Serial => "single-threaded baseline (Table 1)",
            EngineKind::Reference => "coarse-parallel OpenMP analog (Alg. 1)",
            EngineKind::Dpp => "fine-grained DPP pipeline (Alg. 2, paper)",
            EngineKind::Xla => "AOT XLA/PJRT accelerator path",
            EngineKind::Bp => {
                "loopy belief propagation, residual-scheduled DPP sweeps"
            }
            EngineKind::Dual => {
                "MPLP-style dual ascent with certified lower bounds"
            }
            EngineKind::Pmp => {
                "particle max-product over continuous labels (D-PMP)"
            }
        }
    }
}

/// Dataset generation parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetConfig {
    pub kind: DatasetKind,
    pub width: usize,
    pub height: usize,
    pub slices: usize,
    pub seed: u64,
    /// Salt-and-pepper corruption fraction.
    pub salt_pepper: f64,
    /// Additive Gaussian sigma on the 8-bit scale (paper: 100).
    pub gaussian_sigma: f64,
    /// Ringing artifact amplitude (0 disables).
    pub ringing: f64,
}

impl Default for DatasetConfig {
    fn default() -> Self {
        DatasetConfig {
            kind: DatasetKind::Synthetic,
            width: 128,
            height: 128,
            slices: 4,
            seed: 0x5eed,
            salt_pepper: 0.02,
            gaussian_sigma: 100.0,
            ringing: 12.0,
        }
    }
}

/// Oversegmentation parameters (region-merging superpixels).
#[derive(Debug, Clone, PartialEq)]
pub struct OversegConfig {
    /// Felzenszwalb-style scale constant: larger => larger regions.
    pub scale: f64,
    /// Regions smaller than this are merged into a neighbor.
    pub min_region: usize,
}

impl Default for OversegConfig {
    fn default() -> Self {
        OversegConfig { scale: 64.0, min_region: 8 }
    }
}

/// MRF optimization parameters (§3.2.2).
#[derive(Debug, Clone, PartialEq)]
pub struct MrfConfig {
    /// Potts smoothness weight.
    pub beta: f64,
    /// EM outer iterations (paper: converges within 20).
    pub em_iters: usize,
    /// MAP inner iterations per EM iteration.
    pub map_iters: usize,
    /// Convergence window length L (paper: 3).
    pub window: usize,
    /// Relative energy-change threshold (paper: 1e-4).
    pub threshold: f64,
    /// Random init seed for labels/params.
    pub seed: u64,
    /// Disable convergence checks (fixed iteration counts) so engines
    /// are bit-for-bit comparable in tests.
    pub fixed_iters: bool,
}

impl Default for MrfConfig {
    fn default() -> Self {
        MrfConfig {
            beta: 0.5,
            em_iters: 20,
            map_iters: 10,
            window: 3,
            threshold: 1e-4,
            seed: 0xC0FFEE,
            fixed_iters: false,
        }
    }
}

/// Slice-scheduler shape (DESIGN.md §8): how many lanes shard the
/// slice stack and how far initialization may run ahead of
/// optimization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchedConfig {
    /// Optimize lanes (init/optimize worker pairs). 1 reproduces the
    /// serial slice order bitwise; each extra lane adds roughly
    /// `threads` worker threads (lanes oversubscribe when
    /// `threads > 1`).
    pub lanes: usize,
    /// Max initialized-but-unoptimized slice models waiting between
    /// the init and optimize stages (backpressure / peak-memory cap).
    pub inflight: usize,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig { lanes: 1, inflight: 2 }
    }
}

/// Observability switches (DESIGN.md §11): both default off, so the
/// hot path stays bitwise-identical and allocation-free unless a run
/// opts in.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct TelemetryConfig {
    /// Enable the global metric registry (`--profile`): primitive
    /// wall-time rows, workspace counters, and the `timing::report`
    /// table at the end of the run.
    pub profile: bool,
    /// Write a Chrome trace-event JSON file of the run's span tree
    /// (`--trace-out <file>`); `None` disables tracing entirely.
    pub trace_out: Option<PathBuf>,
}

/// Observability shape (DESIGN.md §13): convergence flight recorder,
/// serving SLOs, and Prometheus-style metrics exposition. Everything
/// defaults off / permissive, so an unconfigured run stays
/// bitwise-identical and allocation-free.
#[derive(Debug, Clone, PartialEq)]
pub struct ObsConfig {
    /// Dump the full convergence journal as JSONL to this file
    /// (`--convergence-out <file>`); `None` leaves the recorder
    /// disarmed.
    pub convergence_out: Option<PathBuf>,
    /// Ring capacity of the flight recorder in samples (oldest
    /// samples are overwritten past this; `dropped` counts them).
    pub convergence_cap: usize,
    /// Write the Prometheus text-format metrics exposition to this
    /// file at the end of the run (`--metrics-out <file>`); implies
    /// `telemetry.profile` so the timing registry has rows to export.
    pub metrics_out: Option<PathBuf>,
    /// Serving SLO thresholds (all `None` = no SLO accounting).
    pub slo: crate::obs::SloConfig,
    /// Busy-lane heartbeat silence, in seconds, before a service lane
    /// is reported as stalled by [`crate::sched::Service::health`].
    pub stall_window_secs: f64,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            convergence_out: None,
            convergence_cap: crate::obs::DEFAULT_CAPACITY,
            metrics_out: None,
            slo: crate::obs::SloConfig::default(),
            stall_window_secs: 30.0,
        }
    }
}

/// Everything one run needs.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    pub dataset: DatasetConfig,
    pub overseg: OversegConfig,
    pub mrf: MrfConfig,
    /// BP engine parameters (used when `engine` is [`EngineKind::Bp`]).
    pub bp: BpConfig,
    /// Dual engine parameters (used when `engine` is
    /// [`EngineKind::Dual`]).
    pub dual: DualConfig,
    /// Particle max-product parameters (used when `engine` is
    /// [`EngineKind::Pmp`]).
    pub pmp: PmpConfig,
    /// Slice-scheduler shape (`--lanes` / `--inflight`).
    pub sched: SchedConfig,
    /// Observability switches (`--profile` / `--trace-out`).
    pub telemetry: TelemetryConfig,
    /// Flight recorder / SLO / metrics-exposition shape
    /// (`--convergence-out` / `--metrics-out`).
    pub obs: ObsConfig,
    pub engine: EngineKind,
    /// Which [`crate::dpp::Device`] the primitives execute on
    /// (`--device`): `auto` keeps the historical serial-for-one-thread
    /// rule, `serial`/`pool`/`accel` pin a device explicitly.
    pub device: DeviceKind,
    pub threads: usize,
    pub grain: usize,
    pub artifacts_dir: PathBuf,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: DatasetConfig::default(),
            overseg: OversegConfig::default(),
            mrf: MrfConfig::default(),
            bp: BpConfig::default(),
            dual: DualConfig::default(),
            pmp: PmpConfig::default(),
            sched: SchedConfig::default(),
            telemetry: TelemetryConfig::default(),
            obs: ObsConfig::default(),
            engine: EngineKind::Dpp,
            device: DeviceKind::Auto,
            threads: crate::pool::available_threads(),
            grain: crate::pool::DEFAULT_GRAIN,
            artifacts_dir: PathBuf::from("artifacts"),
        }
    }
}

fn get_f64(v: &Value, key: &str, default: f64) -> f64 {
    v.get(key).and_then(Value::as_f64).unwrap_or(default)
}

fn get_usize(v: &Value, key: &str, default: usize) -> usize {
    v.get(key).and_then(Value::as_usize).unwrap_or(default)
}

fn get_u64(v: &Value, key: &str, default: u64) -> u64 {
    v.get(key).and_then(Value::as_i64).map(|i| i as u64).unwrap_or(default)
}

fn opt_f64(v: Option<f64>) -> Value {
    v.map_or(Value::Null, Value::from)
}

impl RunConfig {
    /// Load from a JSON file; unknown keys are ignored, missing keys get
    /// defaults, malformed values are errors.
    pub fn from_json_file(path: &Path) -> Result<RunConfig> {
        let v = json::from_file(path)?;
        Self::from_json(&v)
            .with_context(|| format!("in config {}", path.display()))
    }

    pub fn from_json(v: &Value) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        if let Some(d) = v.get("dataset") {
            if let Some(k) = d.get("kind").and_then(Value::as_str) {
                cfg.dataset.kind = DatasetKind::parse(k)?;
            }
            cfg.dataset.width = get_usize(d, "width", cfg.dataset.width);
            cfg.dataset.height = get_usize(d, "height", cfg.dataset.height);
            cfg.dataset.slices = get_usize(d, "slices", cfg.dataset.slices);
            cfg.dataset.seed = get_u64(d, "seed", cfg.dataset.seed);
            cfg.dataset.salt_pepper =
                get_f64(d, "salt_pepper", cfg.dataset.salt_pepper);
            cfg.dataset.gaussian_sigma =
                get_f64(d, "gaussian_sigma", cfg.dataset.gaussian_sigma);
            cfg.dataset.ringing = get_f64(d, "ringing", cfg.dataset.ringing);
        }
        if let Some(o) = v.get("overseg") {
            cfg.overseg.scale = get_f64(o, "scale", cfg.overseg.scale);
            cfg.overseg.min_region =
                get_usize(o, "min_region", cfg.overseg.min_region);
        }
        if let Some(m) = v.get("mrf") {
            cfg.mrf.beta = get_f64(m, "beta", cfg.mrf.beta);
            cfg.mrf.em_iters = get_usize(m, "em_iters", cfg.mrf.em_iters);
            cfg.mrf.map_iters = get_usize(m, "map_iters", cfg.mrf.map_iters);
            cfg.mrf.window = get_usize(m, "window", cfg.mrf.window);
            cfg.mrf.threshold = get_f64(m, "threshold", cfg.mrf.threshold);
            cfg.mrf.seed = get_u64(m, "seed", cfg.mrf.seed);
            cfg.mrf.fixed_iters = m
                .get("fixed_iters")
                .and_then(Value::as_bool)
                .unwrap_or(cfg.mrf.fixed_iters);
        }
        if let Some(b) = v.get("bp") {
            if let Some(s) = b.get("schedule").and_then(Value::as_str) {
                cfg.bp.schedule = BpSchedule::parse(s)?;
            }
            cfg.bp.damping =
                get_f64(b, "damping", cfg.bp.damping as f64) as f32;
            cfg.bp.max_sweeps =
                get_usize(b, "max_sweeps", cfg.bp.max_sweeps);
            cfg.bp.tol = get_f64(b, "tol", cfg.bp.tol as f64) as f32;
            cfg.bp.frontier =
                get_f64(b, "frontier", cfg.bp.frontier as f64) as f32;
        }
        if let Some(d) = v.get("dual") {
            cfg.dual.iters = get_usize(d, "iters", cfg.dual.iters);
            cfg.dual.tol = get_f64(d, "tol", cfg.dual.tol);
        }
        if let Some(p) = v.get("pmp") {
            cfg.pmp.particles =
                get_usize(p, "particles", cfg.pmp.particles);
            cfg.pmp.iters = get_usize(p, "iters", cfg.pmp.iters);
            cfg.pmp.sweeps = get_usize(p, "sweeps", cfg.pmp.sweeps);
            cfg.pmp.walk_sigma =
                get_f64(p, "walk_sigma", cfg.pmp.walk_sigma as f64)
                    as f32;
            cfg.pmp.tol = get_f64(p, "tol", cfg.pmp.tol);
            cfg.pmp.seed = get_u64(p, "seed", cfg.pmp.seed);
        }
        if let Some(s) = v.get("sched") {
            cfg.sched.lanes = get_usize(s, "lanes", cfg.sched.lanes);
            cfg.sched.inflight =
                get_usize(s, "inflight", cfg.sched.inflight);
        }
        if let Some(t) = v.get("telemetry") {
            cfg.telemetry.profile = t
                .get("profile")
                .and_then(Value::as_bool)
                .unwrap_or(cfg.telemetry.profile);
            // `"trace_out": null` (and a missing key) both mean off.
            cfg.telemetry.trace_out = t
                .get("trace_out")
                .and_then(Value::as_str)
                .map(PathBuf::from);
        }
        if let Some(o) = v.get("obs") {
            // `null` and a missing key both mean off for the outputs
            // and "no threshold" for the SLO knobs.
            cfg.obs.convergence_out = o
                .get("convergence_out")
                .and_then(Value::as_str)
                .map(PathBuf::from);
            cfg.obs.convergence_cap =
                get_usize(o, "convergence_cap", cfg.obs.convergence_cap);
            cfg.obs.metrics_out = o
                .get("metrics_out")
                .and_then(Value::as_str)
                .map(PathBuf::from);
            if let Some(s) = o.get("slo") {
                cfg.obs.slo.max_gap =
                    s.get("max_gap").and_then(Value::as_f64);
                cfg.obs.slo.max_queue_wait =
                    s.get("max_queue_wait").and_then(Value::as_f64);
                cfg.obs.slo.max_job_latency =
                    s.get("max_job_latency").and_then(Value::as_f64);
            }
            cfg.obs.stall_window_secs = get_f64(
                o, "stall_window_secs", cfg.obs.stall_window_secs,
            );
        }
        if let Some(e) = v.get("engine").and_then(Value::as_str) {
            cfg.engine = EngineKind::parse(e)?;
        }
        if let Some(d) = v.get("device").and_then(Value::as_str) {
            cfg.device = DeviceKind::parse(d)?;
        }
        cfg.threads = get_usize(v, "threads", cfg.threads);
        cfg.grain = get_usize(v, "grain", cfg.grain);
        if let Some(p) = v.get("artifacts_dir").and_then(Value::as_str) {
            cfg.artifacts_dir = PathBuf::from(p);
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Range checks shared by the JSON loader and the CLI override
    /// path (`main.rs` re-validates after applying `--bp-*` flags).
    pub fn validate(&self) -> Result<()> {
        if self.threads == 0 {
            bail!("threads must be >= 1");
        }
        if self.mrf.window == 0 {
            bail!("mrf.window must be >= 1");
        }
        self.bp.schedule.validate()?;
        if !(0.0..1.0).contains(&self.bp.damping) {
            bail!("bp.damping must be in [0, 1)");
        }
        if !(0.0..=1.0).contains(&self.bp.frontier) {
            bail!("bp.frontier must be in [0, 1]");
        }
        if self.bp.max_sweeps == 0 {
            bail!("bp.max_sweeps must be >= 1");
        }
        if self.bp.tol <= 0.0 {
            bail!("bp.tol must be > 0");
        }
        if self.dual.iters == 0 {
            bail!("dual.iters must be >= 1");
        }
        if !self.dual.tol.is_finite() || self.dual.tol < 0.0 {
            bail!("dual.tol must be finite and >= 0");
        }
        if self.pmp.particles == 0 {
            bail!("pmp.particles must be >= 1");
        }
        if self.pmp.iters == 0 {
            bail!("pmp.iters must be >= 1");
        }
        if self.pmp.sweeps == 0 {
            bail!("pmp.sweeps must be >= 1");
        }
        if !self.pmp.walk_sigma.is_finite() || self.pmp.walk_sigma < 0.0
        {
            bail!("pmp.walk_sigma must be finite and >= 0");
        }
        if !self.pmp.tol.is_finite() || self.pmp.tol < 0.0 {
            bail!("pmp.tol must be finite and >= 0");
        }
        if self.sched.lanes == 0 {
            bail!("sched.lanes must be >= 1");
        }
        if self.sched.inflight == 0 {
            bail!("sched.inflight must be >= 1");
        }
        if self.obs.convergence_cap < 2 {
            bail!("obs.convergence_cap must be >= 2");
        }
        if !(self.obs.stall_window_secs.is_finite()
            && self.obs.stall_window_secs > 0.0)
        {
            bail!("obs.stall_window_secs must be finite and > 0");
        }
        for (name, v) in [
            ("max_gap", self.obs.slo.max_gap),
            ("max_queue_wait", self.obs.slo.max_queue_wait),
            ("max_job_latency", self.obs.slo.max_job_latency),
        ] {
            if let Some(x) = v {
                if !x.is_finite() || x < 0.0 {
                    bail!("obs.slo.{name} must be finite and >= 0");
                }
            }
        }
        Ok(())
    }

    /// Serialize back to JSON (round-trips through `from_json`).
    pub fn to_json(&self) -> Value {
        Value::object(vec![
            ("dataset", Value::object(vec![
                ("kind", self.dataset.kind.name().into()),
                ("width", self.dataset.width.into()),
                ("height", self.dataset.height.into()),
                ("slices", self.dataset.slices.into()),
                ("seed", (self.dataset.seed as usize).into()),
                ("salt_pepper", self.dataset.salt_pepper.into()),
                ("gaussian_sigma", self.dataset.gaussian_sigma.into()),
                ("ringing", self.dataset.ringing.into()),
            ])),
            ("overseg", Value::object(vec![
                ("scale", self.overseg.scale.into()),
                ("min_region", self.overseg.min_region.into()),
            ])),
            ("mrf", Value::object(vec![
                ("beta", self.mrf.beta.into()),
                ("em_iters", self.mrf.em_iters.into()),
                ("map_iters", self.mrf.map_iters.into()),
                ("window", self.mrf.window.into()),
                ("threshold", self.mrf.threshold.into()),
                ("seed", (self.mrf.seed as usize).into()),
                ("fixed_iters", self.mrf.fixed_iters.into()),
            ])),
            ("bp", Value::object(vec![
                ("damping", (self.bp.damping as f64).into()),
                ("max_sweeps", self.bp.max_sweeps.into()),
                ("tol", (self.bp.tol as f64).into()),
                ("schedule", Value::str(self.bp.schedule.spec())),
                ("frontier", (self.bp.frontier as f64).into()),
            ])),
            ("dual", Value::object(vec![
                ("iters", self.dual.iters.into()),
                ("tol", self.dual.tol.into()),
            ])),
            ("pmp", Value::object(vec![
                ("particles", self.pmp.particles.into()),
                ("iters", self.pmp.iters.into()),
                ("sweeps", self.pmp.sweeps.into()),
                ("walk_sigma", (self.pmp.walk_sigma as f64).into()),
                ("tol", self.pmp.tol.into()),
                ("seed", (self.pmp.seed as usize).into()),
            ])),
            ("sched", Value::object(vec![
                ("lanes", self.sched.lanes.into()),
                ("inflight", self.sched.inflight.into()),
            ])),
            ("telemetry", Value::object(vec![
                ("profile", self.telemetry.profile.into()),
                ("trace_out", match &self.telemetry.trace_out {
                    Some(p) => p.to_string_lossy().as_ref().into(),
                    None => Value::Null,
                }),
            ])),
            ("obs", Value::object(vec![
                ("convergence_out", match &self.obs.convergence_out {
                    Some(p) => p.to_string_lossy().as_ref().into(),
                    None => Value::Null,
                }),
                ("convergence_cap", self.obs.convergence_cap.into()),
                ("metrics_out", match &self.obs.metrics_out {
                    Some(p) => p.to_string_lossy().as_ref().into(),
                    None => Value::Null,
                }),
                ("slo", Value::object(vec![
                    ("max_gap", opt_f64(self.obs.slo.max_gap)),
                    ("max_queue_wait",
                     opt_f64(self.obs.slo.max_queue_wait)),
                    ("max_job_latency",
                     opt_f64(self.obs.slo.max_job_latency)),
                ])),
                ("stall_window_secs", self.obs.stall_window_secs.into()),
            ])),
            ("engine", self.engine.name().into()),
            ("device", self.device.name().into()),
            ("threads", self.threads.into()),
            ("grain", self.grain.into()),
            ("artifacts_dir",
             self.artifacts_dir.to_string_lossy().as_ref().into()),
        ])
    }

    pub fn save_json(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_pretty())
            .with_context(|| format!("write {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_round_trip() {
        let cfg = RunConfig::default();
        let v = cfg.to_json();
        let back = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn partial_json_gets_defaults() {
        let v = json::parse(r#"{"engine": "serial", "threads": 2}"#).unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.engine, EngineKind::Serial);
        assert_eq!(cfg.threads, 2);
        assert_eq!(cfg.mrf.em_iters, 20);
    }

    #[test]
    fn rejects_bad_values() {
        let v = json::parse(r#"{"engine": "magic"}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"threads": 0}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"bp": {"damping": 1.5}}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"bp": {"schedule": "chaotic"}}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"bp": {"schedule": "bucketed:1"}}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"bp": {"schedule": "random:1.5"}}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"bp": {"max_sweeps": 0}}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"bp": {"tol": -1.0}}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"dual": {"iters": 0}}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"dual": {"tol": -1.0}}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"pmp": {"particles": 0}}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"pmp": {"iters": 0}}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"pmp": {"sweeps": 0}}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"pmp": {"walk_sigma": -2.0}}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"pmp": {"tol": -1.0}}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"sched": {"lanes": 0}}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"sched": {"inflight": 0}}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
    }

    #[test]
    fn kinds_parse_and_name() {
        for k in
            ["serial", "reference", "dpp", "xla", "bp", "dual", "pmp"]
        {
            assert_eq!(EngineKind::parse(k).unwrap().name(), k);
        }
        assert_eq!(EngineKind::all().len(), 7);
        for d in ["synthetic", "experimental"] {
            assert_eq!(DatasetKind::parse(d).unwrap().name(), d);
        }
        for d in ["auto", "serial", "pool", "accel"] {
            assert_eq!(DeviceKind::parse(d).unwrap().name(), d);
        }
    }

    #[test]
    fn device_section_parses_with_default() {
        let v = json::parse(r#"{"device": "pool"}"#).unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.device, DeviceKind::Pool);
        let v = json::parse(r#"{"threads": 2}"#).unwrap();
        assert_eq!(
            RunConfig::from_json(&v).unwrap().device,
            DeviceKind::Auto
        );
        let v = json::parse(r#"{"device": "gpu"}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
    }

    #[test]
    fn bp_section_parses() {
        let v = json::parse(
            r#"{"engine": "bp", "bp": {"damping": 0.25, "max_sweeps": 9,
                "schedule": "sync", "frontier": 0.75}}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.engine, EngineKind::Bp);
        assert_eq!(cfg.bp.damping, 0.25);
        assert_eq!(cfg.bp.max_sweeps, 9);
        assert_eq!(cfg.bp.schedule, BpSchedule::Synchronous);
        assert_eq!(cfg.bp.frontier, 0.75);
        // unspecified keys keep defaults
        assert_eq!(cfg.bp.tol, BpConfig::default().tol);
    }

    #[test]
    fn parameterized_bp_schedules_round_trip_through_json() {
        for (spec, want) in [
            ("stale", BpSchedule::StaleResidual),
            ("bucketed:4", BpSchedule::Bucketed { bins: 4 }),
            (
                "random:0.25:99",
                BpSchedule::RandomizedSubset { p: 0.25, seed: 99 },
            ),
        ] {
            let v = json::parse(&format!(
                r#"{{"bp": {{"schedule": "{spec}"}}}}"#
            ))
            .unwrap();
            let cfg = RunConfig::from_json(&v).unwrap();
            assert_eq!(cfg.bp.schedule, want, "parse {spec}");
            let back = RunConfig::from_json(&cfg.to_json()).unwrap();
            assert_eq!(back.bp.schedule, want, "round-trip {spec}");
        }
    }

    #[test]
    fn dual_section_parses() {
        let v = json::parse(
            r#"{"engine": "dual", "dual": {"iters": 33, "tol": 1e-7}}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.engine, EngineKind::Dual);
        assert_eq!(cfg.dual.iters, 33);
        assert_eq!(cfg.dual.tol, 1e-7);
        // unspecified keys keep defaults
        let v = json::parse(r#"{"dual": {"iters": 5}}"#).unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.dual.iters, 5);
        assert_eq!(cfg.dual.tol, DualConfig::default().tol);
        // and the section round-trips through to_json
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn pmp_section_parses_and_round_trips() {
        let v = json::parse(
            r#"{"engine": "pmp", "pmp": {"particles": 4, "iters": 8,
                "sweeps": 2, "walk_sigma": 6.5, "tol": 1e-5}}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.engine, EngineKind::Pmp);
        assert_eq!(cfg.pmp.particles, 4);
        assert_eq!(cfg.pmp.iters, 8);
        assert_eq!(cfg.pmp.sweeps, 2);
        assert_eq!(cfg.pmp.walk_sigma, 6.5);
        assert_eq!(cfg.pmp.tol, 1e-5);
        // unspecified keys keep defaults
        assert_eq!(cfg.pmp.seed, PmpConfig::default().seed);
        // and the section round-trips through to_json
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn telemetry_section_parses_and_round_trips() {
        let v = json::parse(
            r#"{"telemetry": {"profile": true, "trace_out": "t.json"}}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert!(cfg.telemetry.profile);
        assert_eq!(cfg.telemetry.trace_out,
                   Some(PathBuf::from("t.json")));
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        // Explicit null and a missing section both mean off.
        let v = json::parse(r#"{"telemetry": {"trace_out": null}}"#)
            .unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.telemetry, TelemetryConfig::default());
    }

    #[test]
    fn obs_section_parses_validates_and_round_trips() {
        let v = json::parse(
            r#"{"obs": {"convergence_out": "conv.jsonl",
                "convergence_cap": 128, "metrics_out": "m.prom",
                "slo": {"max_gap": 1.5, "max_job_latency": 0.25},
                "stall_window_secs": 5.0}}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.obs.convergence_out,
                   Some(PathBuf::from("conv.jsonl")));
        assert_eq!(cfg.obs.convergence_cap, 128);
        assert_eq!(cfg.obs.metrics_out, Some(PathBuf::from("m.prom")));
        assert_eq!(cfg.obs.slo.max_gap, Some(1.5));
        assert_eq!(cfg.obs.slo.max_queue_wait, None);
        assert_eq!(cfg.obs.slo.max_job_latency, Some(0.25));
        assert_eq!(cfg.obs.stall_window_secs, 5.0);
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        // Missing section and explicit nulls both mean off.
        let v = json::parse(
            r#"{"obs": {"convergence_out": null, "metrics_out": null,
                "slo": {"max_gap": null}}}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.obs, ObsConfig::default());
        // Bad values are rejected.
        let v = json::parse(r#"{"obs": {"convergence_cap": 1}}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"obs": {"stall_window_secs": 0}}"#)
            .unwrap();
        assert!(RunConfig::from_json(&v).is_err());
        let v =
            json::parse(r#"{"obs": {"slo": {"max_gap": -1.0}}}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
    }

    #[test]
    fn sched_section_parses_with_defaults() {
        let v = json::parse(r#"{"sched": {"lanes": 4}}"#).unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.sched.lanes, 4);
        assert_eq!(cfg.sched.inflight, SchedConfig::default().inflight);
        let v = json::parse(r#"{"sched": {"lanes": 2, "inflight": 7}}"#)
            .unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.sched, SchedConfig { lanes: 2, inflight: 7 });
    }
}
