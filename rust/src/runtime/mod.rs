//! XLA/PJRT runtime — loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes the EM inner step from rust.
//!
//! Python never runs on this path: `make artifacts` lowers the L2 JAX
//! model (containing the L1 Pallas kernel) to HLO *text* once; here we
//! parse that text (`HloModuleProto::from_text_file` — the text parser
//! reassigns the 64-bit instruction ids jax ≥ 0.5 emits, which
//! xla_extension 0.5.1 would reject in proto form), compile one PJRT
//! executable per size bucket, and dispatch padded batches.
//!
//! This is the paper's "GPU back end" stand-in (DESIGN.md
//! §Hardware-Adaptation): the identical code path a TPU/GPU PJRT plugin
//! would serve, exercised on the CPU client.

pub mod xla;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::dpp::timing;
use crate::mrf::Params;

/// One compiled size bucket.
pub struct Bucket {
    pub elems: usize,
    pub hoods: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// Outputs of one EM-step dispatch, already trimmed to the real
/// (unpadded) sizes.
#[derive(Debug, Clone)]
pub struct EmStepOut {
    /// Per-element argmin label (0.0/1.0).
    pub new_label: Vec<f32>,
    /// Per-element minimum energy.
    pub emin: Vec<f32>,
    /// Per-hood energy sums.
    pub hood_energy: Vec<f32>,
    /// (count0, sum0, sumsq0, count1, sum1, sumsq1).
    pub stats: [f32; 6],
    /// Global energy sum.
    pub total: f32,
}

/// One compiled in-device-loop bucket (§Perf L2: the K-iteration MAP
/// loop runs inside the artifact — one dispatch per EM iteration).
pub struct LoopBucket {
    pub elems: usize,
    pub hoods: usize,
    pub verts: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// Outputs of one em_loop dispatch (final-iteration values, trimmed).
#[derive(Debug, Clone)]
pub struct EmLoopOut {
    /// Per-vertex labels after K MAP iterations.
    pub label_v: Vec<f32>,
    pub hood_energy: Vec<f32>,
    pub stats: [f32; 6],
    pub total: f32,
}

/// The PJRT client plus all compiled buckets, ready to serve EM steps.
pub struct EmRuntime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    buckets: Vec<Bucket>,
    loop_buckets: Vec<LoopBucket>,
    pub dir: PathBuf,
}

impl EmRuntime {
    /// Load every bucket listed in `<dir>/manifest.json` and compile it
    /// on the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<EmRuntime> {
        let manifest = crate::json::from_file(&dir.join("manifest.json"))
            .context("artifacts manifest (run `make artifacts`?)")?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        let mut buckets = Vec::new();
        for b in manifest
            .get("buckets")
            .and_then(|v| v.as_array())
            .ok_or_else(|| anyhow!("manifest missing buckets"))?
        {
            let elems = b
                .get("elems")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("bucket missing elems"))?;
            let hoods = b
                .get("hoods")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("bucket missing hoods"))?;
            let file = b
                .get("file")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("bucket missing file"))?;
            let path = dir.join(file);
            let t = crate::util::Timer::start();
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e}", path.display()))?;
            crate::log_debug!(
                "compiled bucket n={elems} h={hoods} in {}",
                crate::util::fmt_secs(t.elapsed_secs())
            );
            buckets.push(Bucket { elems, hoods, exe });
        }
        if buckets.is_empty() {
            bail!("no buckets in manifest");
        }
        buckets.sort_by_key(|b| (b.elems, b.hoods));

        // Loop buckets are optional (older artifact sets lack them).
        let mut loop_buckets = Vec::new();
        if let Some(list) =
            manifest.get("loop_buckets").and_then(|v| v.as_array())
        {
            for b in list {
                let (Some(elems), Some(hoods), Some(verts), Some(file)) = (
                    b.get("elems").and_then(|v| v.as_usize()),
                    b.get("hoods").and_then(|v| v.as_usize()),
                    b.get("verts").and_then(|v| v.as_usize()),
                    b.get("file").and_then(|v| v.as_str()),
                ) else {
                    bail!("malformed loop_bucket entry");
                };
                let path = dir.join(file);
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client.compile(&comp).map_err(|e| {
                    anyhow!("compile {}: {e}", path.display())
                })?;
                loop_buckets.push(LoopBucket { elems, hoods, verts, exe });
            }
            loop_buckets.sort_by_key(|b| (b.elems, b.hoods));
        }
        Ok(EmRuntime { client, buckets, loop_buckets, dir: dir.to_path_buf() })
    }

    /// Smallest loop bucket that fits `(elems, hoods, verts)`.
    pub fn pick_loop_bucket(&self, elems: usize, hoods: usize, verts: usize)
        -> Result<&LoopBucket> {
        self.loop_buckets
            .iter()
            .find(|b| b.elems >= elems && b.hoods >= hoods
                      && b.verts >= verts)
            .ok_or_else(|| anyhow!(
                "no loop bucket fits (elems={elems}, hoods={hoods}, \
                 verts={verts}); re-run `make artifacts`"))
    }

    pub fn has_loop_buckets(&self) -> bool {
        !self.loop_buckets.is_empty()
    }

    /// Execute K MAP iterations in one dispatch. `vert_elems` /
    /// `vert_seg` describe the by-vertex grouping of elements (see
    /// `python/compile/model.py::em_loop`). Padding reserves the last
    /// hood and the last vertex of the bucket as sacrificial targets.
    #[allow(clippy::too_many_arguments)]
    pub fn em_loop(
        &self,
        y: &[f32],
        label_v: &[f32],
        hood_id: &[u32],
        members: &[u32],
        vert_elems: &[u32],
        vert_seg: &[u32],
        num_hoods: usize,
        k: usize,
        prm: &Params,
    ) -> Result<EmLoopOut> {
        let n = y.len();
        let nv = label_v.len();
        assert_eq!(hood_id.len(), n);
        assert_eq!(members.len(), n);
        assert_eq!(vert_elems.len(), n);
        assert_eq!(vert_seg.len(), n);
        let bucket = self.pick_loop_bucket(n, num_hoods + 1, nv + 1)?;
        let (bn, bh, bv) = (bucket.elems, bucket.hoods, bucket.verts);

        let pad_i32 = |src: &[u32], fill: i32| -> Vec<i32> {
            let mut out = vec![fill; bn];
            for (dst, &s) in out.iter_mut().zip(src.iter()) {
                *dst = s as i32;
            }
            out
        };
        let mut y_p = vec![0.0f32; bn];
        y_p[..n].copy_from_slice(y);
        let mut l_p = vec![0.0f32; bv];
        l_p[..nv].copy_from_slice(label_v);
        let h_p = pad_i32(hood_id, (bh - 1) as i32);
        let m_p = pad_i32(members, (bv - 1) as i32);
        let ve_p = pad_i32(vert_elems, 0);
        let vs_p = pad_i32(vert_seg, (bv - 1) as i32);
        let mut v_p = vec![0.0f32; bn];
        v_p[..n].fill(1.0);
        let params_v =
            [prm.mu[0], prm.mu[1], prm.sigma[0], prm.sigma[1], prm.beta];
        let k_v = [k as i32];

        let t = crate::util::Timer::start();
        let args = [
            xla::Literal::vec1(&y_p),
            xla::Literal::vec1(&l_p),
            xla::Literal::vec1(&h_p),
            xla::Literal::vec1(&m_p),
            xla::Literal::vec1(&v_p),
            xla::Literal::vec1(&ve_p),
            xla::Literal::vec1(&vs_p),
            xla::Literal::vec1(&k_v[..]),
            xla::Literal::vec1(&params_v[..]),
        ];
        let result = bucket
            .exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("execute em_loop: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e}"))?;
        let outs = result.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
        timing::record("XlaEmLoop", t.elapsed().as_nanos() as u64);
        if outs.len() != 4 {
            bail!("expected 4 outputs, got {}", outs.len());
        }
        let mut it = outs.into_iter();
        let label_out: Vec<f32> =
            it.next().unwrap().to_vec().map_err(|e| anyhow!("{e}"))?;
        let hood_energy: Vec<f32> =
            it.next().unwrap().to_vec().map_err(|e| anyhow!("{e}"))?;
        let stats_v: Vec<f32> =
            it.next().unwrap().to_vec().map_err(|e| anyhow!("{e}"))?;
        let total_v: Vec<f32> =
            it.next().unwrap().to_vec().map_err(|e| anyhow!("{e}"))?;
        let mut stats = [0.0f32; 6];
        stats.copy_from_slice(&stats_v);
        Ok(EmLoopOut {
            label_v: label_out[..nv].to_vec(),
            hood_energy: hood_energy[..num_hoods].to_vec(),
            stats,
            total: total_v[0],
        })
    }

    /// Smallest bucket that fits `(elems, hoods)`.
    pub fn pick_bucket(&self, elems: usize, hoods: usize) -> Result<&Bucket> {
        self.buckets
            .iter()
            .find(|b| b.elems >= elems && b.hoods >= hoods)
            .ok_or_else(|| {
                anyhow!(
                    "batch (elems={elems}, hoods={hoods}) exceeds largest \
                     bucket (elems={}, hoods={})",
                    self.buckets.last().unwrap().elems,
                    self.buckets.last().unwrap().hoods
                )
            })
    }

    pub fn buckets(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.buckets.iter().map(|b| (b.elems, b.hoods))
    }

    /// Execute one EM step. Inputs are the *real* (unpadded) arrays;
    /// padding to the bucket shape happens here. Padding elements carry
    /// `valid = 0` and point at the last (sacrificial) hood.
    pub fn em_step(
        &self,
        y: &[f32],
        label: &[f32],
        hood_id: &[u32],
        num_hoods: usize,
        prm: &Params,
    ) -> Result<EmStepOut> {
        let n = y.len();
        assert_eq!(label.len(), n);
        assert_eq!(hood_id.len(), n);
        // Reserve one hood id for padding so real hood energies are
        // untouched by the padded lanes.
        let bucket = self.pick_bucket(n, num_hoods + 1)?;
        let (bn, bh) = (bucket.elems, bucket.hoods);

        let mut y_p = vec![0.0f32; bn];
        y_p[..n].copy_from_slice(y);
        let mut l_p = vec![0.0f32; bn];
        l_p[..n].copy_from_slice(label);
        let mut h_p = vec![(bh - 1) as i32; bn];
        for (dst, &src) in h_p.iter_mut().zip(hood_id.iter()) {
            *dst = src as i32;
        }
        let mut v_p = vec![0.0f32; bn];
        v_p[..n].fill(1.0);
        let params_v =
            [prm.mu[0], prm.mu[1], prm.sigma[0], prm.sigma[1], prm.beta];

        let t = crate::util::Timer::start();
        let lit_y = xla::Literal::vec1(&y_p);
        let lit_l = xla::Literal::vec1(&l_p);
        let lit_h = xla::Literal::vec1(&h_p);
        let lit_v = xla::Literal::vec1(&v_p);
        let lit_p = xla::Literal::vec1(&params_v[..]);

        let result = bucket
            .exe
            .execute::<xla::Literal>(&[lit_y, lit_l, lit_h, lit_v, lit_p])
            .map_err(|e| anyhow!("execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e}"))?;
        let outs =
            result.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
        timing::record("XlaEmStep", t.elapsed().as_nanos() as u64);
        if outs.len() != 5 {
            bail!("expected 5 outputs, got {}", outs.len());
        }
        let mut it = outs.into_iter();
        let new_label: Vec<f32> =
            it.next().unwrap().to_vec().map_err(|e| anyhow!("{e}"))?;
        let emin: Vec<f32> =
            it.next().unwrap().to_vec().map_err(|e| anyhow!("{e}"))?;
        let hood_energy: Vec<f32> =
            it.next().unwrap().to_vec().map_err(|e| anyhow!("{e}"))?;
        let stats_v: Vec<f32> =
            it.next().unwrap().to_vec().map_err(|e| anyhow!("{e}"))?;
        let total_v: Vec<f32> =
            it.next().unwrap().to_vec().map_err(|e| anyhow!("{e}"))?;

        let mut stats = [0.0f32; 6];
        stats.copy_from_slice(&stats_v);
        Ok(EmStepOut {
            new_label: new_label[..n].to_vec(),
            emin: emin[..n].to_vec(),
            hood_energy: hood_energy[..num_hoods].to_vec(),
            stats,
            total: total_v[0],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mrf::energy;

    fn artifacts_dir() -> PathBuf {
        // Tests run from the crate root.
        PathBuf::from("artifacts")
    }

    /// `None` (skip) without AOT artifacts / a real PJRT binding —
    /// offline builds use the stub in `rust/src/runtime/xla.rs`.
    fn runtime() -> Option<EmRuntime> {
        match EmRuntime::load(&artifacts_dir()) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping xla runtime test: {e}");
                None
            }
        }
    }

    #[test]
    fn loads_manifest_buckets() {
        let Some(rt) = runtime() else { return };
        let buckets: Vec<_> = rt.buckets().collect();
        assert!(!buckets.is_empty());
        assert!(buckets.windows(2).all(|w| w[0].0 <= w[1].0), "sorted");
    }

    #[test]
    fn pick_bucket_smallest_fit() {
        let Some(rt) = runtime() else { return };
        let b = rt.pick_bucket(100, 10).unwrap();
        assert_eq!(b.elems, 4096);
        let b = rt.pick_bucket(5000, 10).unwrap();
        assert_eq!(b.elems, 8192);
        assert!(rt.pick_bucket(usize::MAX / 2, 1).is_err());
    }

    #[test]
    fn em_step_matches_rust_energy_math() {
        let Some(rt) = runtime() else { return };
        let prm = Params {
            mu: [40.0, 180.0],
            sigma: [12.0, 30.0],
            beta: 0.5,
        };
        // 3 hoods of 4 elements, mixed labels.
        let n = 12;
        let y: Vec<f32> =
            (0..n).map(|i| 20.0 + 18.0 * i as f32).collect();
        let label: Vec<f32> = (0..n).map(|i| (i % 2) as f32).collect();
        let hood_id: Vec<u32> = (0..n).map(|i| (i / 4) as u32).collect();
        let out = rt.em_step(&y, &label, &hood_id, 3, &prm).unwrap();

        // Oracle: the shared rust energy math.
        let mut ones = [0.0f32; 3];
        for i in 0..n {
            ones[hood_id[i] as usize] += label[i];
        }
        let mut want_he = [0.0f32; 3];
        for i in 0..n {
            let h = hood_id[i] as usize;
            let (em, am) =
                energy::energy_min(y[i], label[i], ones[h], 4.0, &prm);
            assert!(
                (out.emin[i] - em).abs() < 1e-4,
                "emin[{i}]: {} vs {em}", out.emin[i]
            );
            assert_eq!(out.new_label[i], am as f32, "label[{i}]");
            want_he[h] += em;
        }
        for h in 0..3 {
            assert!(
                (out.hood_energy[h] - want_he[h]).abs()
                    < 1e-3 * want_he[h].abs().max(1.0),
                "hood {h}: {} vs {}", out.hood_energy[h], want_he[h]
            );
        }
        let want_total: f32 = want_he.iter().sum();
        assert!((out.total - want_total).abs()
                < 1e-3 * want_total.abs().max(1.0));
        // stats counts add up to n
        assert_eq!((out.stats[0] + out.stats[3]) as usize, n);
    }

    #[test]
    fn padding_does_not_leak_into_outputs() {
        let Some(rt) = runtime() else { return };
        let prm = Params {
            mu: [100.0, 150.0],
            sigma: [10.0, 10.0],
            beta: 0.0,
        };
        // Tiny batch deep inside the smallest bucket.
        let y = vec![90.0f32, 160.0, 140.0];
        let label = vec![0.0f32, 1.0, 0.0];
        let hood_id = vec![0u32, 0, 1];
        let out = rt.em_step(&y, &label, &hood_id, 2, &prm).unwrap();
        assert_eq!(out.new_label.len(), 3);
        assert_eq!(out.hood_energy.len(), 2);
        // beta=0: labels decided purely by distance to mu
        assert_eq!(out.new_label, vec![0.0, 1.0, 1.0]);
        // stats only count the 3 real elements
        assert_eq!((out.stats[0] + out.stats[3]) as usize, 3);
    }
}
