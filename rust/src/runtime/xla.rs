//! Offline stub of the `xla` (PJRT) binding.
//!
//! The runtime was written against the xla-rs API surface
//! (`PjRtClient` / `HloModuleProto` / `Literal` / ...), but the
//! offline registry carries no `xla_extension` crate, so this module
//! gates the dependency instead: the exact subset of the API the
//! runtime calls, with [`PjRtClient::cpu`] reporting the backend as
//! unavailable. Every caller already treats a failed client/load as a
//! clean "xla runtime unavailable" condition (`dpp-pmrf engines`
//! prints it, [`crate::mrf::make_engine`] returns an error for
//! [`crate::config::EngineKind::Xla`]), so the rest of the crate
//! builds and runs without the accelerator. Swapping in a real
//! binding means deleting this file and adding the crate dependency —
//! no call-site changes.

use std::path::Path;

/// Error type standing in for the binding's; callers only `Display`
/// it into `anyhow` contexts.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable() -> XlaError {
    XlaError(
        "xla/PJRT backend not available in this build (offline stub; \
         see rust/src/runtime/xla.rs)"
            .to_string(),
    )
}

/// Parsed HLO module (stub: never constructed successfully).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto, XlaError> {
        Err(unavailable())
    }
}

/// Computation wrapper around a parsed module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Host literal (stub: carries no data — nothing ever executes).
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(unavailable())
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable())
    }
}

/// Compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable())
    }
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    /// Always fails in the offline build; the real binding returns a
    /// CPU client here.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable())
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable())
    }
}
