//! Label parameter initialization and EM re-estimation.
//!
//! Initialization follows the paper (§3.2.2): mu and sigma uniform in
//! the 8-bit intensity range, labels uniform in {0,1} — all from one
//! seeded PCG32 stream so every engine starts identically.
//!
//! Re-estimation mirrors `compile/model.py::update_params`: per label,
//! mu = E[y], sigma = sqrt(max(E[y^2]-mu^2, 0)) floored at
//! [`SIGMA_FLOOR`], over the hood-member instances assigned that label.

use crate::util::Pcg32;

use super::energy::Params;

/// Lower bound on sigma (keeps the Gaussian term finite; same value is
/// baked into the L2 model).
pub const SIGMA_FLOOR: f32 = 1.0;

/// Per-label accumulation: (count, sum_y, sum_y2), f64 accumulators.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Stats {
    pub acc: [[f64; 3]; 2],
}

impl Stats {
    #[inline]
    pub fn add(&mut self, label: u8, y: f32) {
        let a = &mut self.acc[label as usize];
        a[0] += 1.0;
        a[1] += y as f64;
        a[2] += (y as f64) * (y as f64);
    }

    #[inline]
    pub fn merge(&mut self, other: &Stats) {
        for l in 0..2 {
            for k in 0..3 {
                self.acc[l][k] += other.acc[l][k];
            }
        }
    }
}

/// Random initial parameters + labels (deterministic in the seed).
pub fn init_random(num_vertices: usize, beta: f32, seed: u64)
    -> (Params, Vec<u8>) {
    let mut rng = Pcg32::seeded(seed);
    let params = Params {
        mu: [rng.f32() * 255.0, rng.f32() * 255.0],
        sigma: [
            SIGMA_FLOOR + rng.f32() * 126.0,
            SIGMA_FLOOR + rng.f32() * 126.0,
        ],
        beta,
    };
    let labels =
        (0..num_vertices).map(|_| (rng.next_u32() & 1) as u8).collect();
    (params, labels)
}

/// mu/sigma update from accumulated stats; beta is carried through.
/// Empty labels keep a well-defined (floored) parameter set.
pub fn update(stats: &Stats, beta: f32) -> Params {
    let mut mu = [0.0f32; 2];
    let mut sigma = [SIGMA_FLOOR; 2];
    for l in 0..2 {
        let [cnt, s, s2] = stats.acc[l];
        let cnt = cnt.max(1.0);
        let m = s / cnt;
        let var = (s2 / cnt - m * m).max(0.0);
        mu[l] = m as f32;
        sigma[l] = (var.sqrt() as f32).max(SIGMA_FLOOR);
    }
    Params { mu, sigma, beta }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_deterministic_in_range() {
        let (p1, l1) = init_random(100, 0.5, 7);
        let (p2, l2) = init_random(100, 0.5, 7);
        assert_eq!(p1, p2);
        assert_eq!(l1, l2);
        for l in 0..2 {
            assert!((0.0..=255.0).contains(&p1.mu[l]));
            assert!(p1.sigma[l] >= SIGMA_FLOOR);
        }
        assert!(l1.iter().all(|&l| l <= 1));
        assert_ne!(init_random(100, 0.5, 8).1, l1);
    }

    #[test]
    fn update_recovers_moments() {
        let mut st = Stats::default();
        for y in [5.0f32, 15.0] {
            st.add(0, y);
        }
        for y in [100.0f32, 110.0, 120.0] {
            st.add(1, y);
        }
        let p = update(&st, 0.25);
        assert!((p.mu[0] - 10.0).abs() < 1e-6);
        assert!((p.sigma[0] - 5.0).abs() < 1e-5);
        assert!((p.mu[1] - 110.0).abs() < 1e-5);
        assert!((p.sigma[1] - (200.0f32 / 3.0).sqrt()).abs() < 1e-3);
        assert_eq!(p.beta, 0.25);
    }

    #[test]
    fn update_floors_sigma_and_survives_empty() {
        let mut st = Stats::default();
        st.add(1, 50.0); // single point, var = 0; label 0 empty
        let p = update(&st, 0.5);
        assert_eq!(p.sigma[0], SIGMA_FLOOR);
        assert_eq!(p.sigma[1], SIGMA_FLOOR);
        assert_eq!(p.mu[1], 50.0);
        assert!(p.mu[0].is_finite());
    }

    #[test]
    fn merge_equals_sequential() {
        let mut a = Stats::default();
        let mut b = Stats::default();
        let mut whole = Stats::default();
        for i in 0..10 {
            let y = i as f32 * 3.0;
            let l = (i % 2) as u8;
            if i < 5 { a.add(l, y) } else { b.add(l, y) }
            whole.add(l, y);
        }
        a.merge(&b);
        assert_eq!(a, whole);
    }
}
