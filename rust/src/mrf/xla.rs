//! XLA engine — DPP-PMRF with the EM inner step executed as an
//! AOT-compiled XLA program (Table 1's accelerator platform).
//!
//! Per MAP iteration the host only: gathers labels to elements,
//! dispatches one padded batch through [`crate::runtime::EmRuntime`]
//! (per-hood stats, the fused Pallas energy/min kernel, per-hood energy
//! sums, and parameter statistics all happen inside the artifact), then
//! resolves per-vertex labels across hoods and checks convergence.
//! Python is never involved at run time.

use std::sync::Arc;

use crate::config::MrfConfig;
use crate::runtime::EmRuntime;

use super::params::{self, Stats};
use super::{ConvergenceWindow, Engine, EmResult, HoodWindows, MrfModel};

pub struct XlaEngine {
    runtime: Arc<EmRuntime>,
}

impl XlaEngine {
    pub fn new(runtime: Arc<EmRuntime>) -> Self {
        XlaEngine { runtime }
    }
}

impl Engine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn run(&self, model: &MrfModel, cfg: &MrfConfig) -> EmResult {
        if cfg.fixed_iters && self.runtime.has_loop_buckets() {
            // §Perf L2 fast path: the whole K-iteration MAP loop runs
            // inside one artifact dispatch per EM iteration.
            return self.run_fused_loop(model, cfg);
        }
        let h = &model.hoods;
        let n = h.num_elements();
        let nh = h.num_hoods();
        let nv = model.num_vertices();
        let y_elem = model.y_elems();

        let (mut prm, mut labels) =
            params::init_random(nv, cfg.beta as f32, cfg.seed);

        let mut em_window = ConvergenceWindow::new(cfg.window, cfg.threshold);
        let mut total_map = 0usize;
        let mut em_iters = 0usize;
        let mut lbl_e = vec![0.0f32; n];

        for _em in 0..cfg.em_iters {
            em_iters += 1;
            let mut hw = HoodWindows::new(nh, cfg.window, cfg.threshold);
            let mut last_stats = [0.0f32; 6];
            let mut hood_energy = vec![0.0f64; nh];

            for _map in 0..cfg.map_iters {
                total_map += 1;
                // Host gather: labels -> elements.
                for (e, &v) in h.members.iter().enumerate() {
                    lbl_e[e] = labels[v as usize] as f32;
                }
                // One AOT dispatch does the whole inner step.
                let out = self
                    .runtime
                    .em_step(&y_elem, &lbl_e, &h.hood_id, nh, &prm)
                    .expect("EM step dispatch failed");

                // Host: per-vertex resolution across hoods.
                let amin: Vec<u8> =
                    out.new_label.iter().map(|&l| l as u8).collect();
                super::serial::resolve_vertices_serial(
                    model, &out.emin, &amin, &mut labels,
                );

                for (dst, &src) in
                    hood_energy.iter_mut().zip(out.hood_energy.iter())
                {
                    *dst = src as f64;
                }
                last_stats = out.stats;

                let done = hw.push_all(&hood_energy);
                if done && !cfg.fixed_iters {
                    break;
                }
            }

            // Parameter update from the artifact's stats.
            let stats = Stats {
                acc: [
                    [
                        last_stats[0] as f64,
                        last_stats[1] as f64,
                        last_stats[2] as f64,
                    ],
                    [
                        last_stats[3] as f64,
                        last_stats[4] as f64,
                        last_stats[5] as f64,
                    ],
                ],
            };
            prm = params::update(&stats, cfg.beta as f32);

            let total: f64 = hood_energy.iter().sum();
            em_window.push(total);
            if em_window.converged() && !cfg.fixed_iters {
                break;
            }
        }

        EmResult {
            labels,
            em_iters,
            map_iters: total_map,
            energy: *em_window.history().last().unwrap_or(&0.0),
            history: em_window.history().to_vec(),
            params: prm,
            lower_bound: None,
            pmp: None,
            bp: None,
        }
    }
}

impl XlaEngine {
    /// Fixed-iteration path: one `em_loop` dispatch per EM iteration
    /// (labels resolve in-device; only params/energy cross the host
    /// boundary between EM iterations).
    fn run_fused_loop(&self, model: &MrfModel, cfg: &MrfConfig)
        -> EmResult {
        let h = &model.hoods;
        let nh = h.num_hoods();
        let nv = model.num_vertices();
        let y_elem = model.y_elems();

        // Slot -> vertex id for the by-vertex grouping (static).
        let mut vert_seg = vec![0u32; h.num_elements()];
        for v in 0..nv {
            for s in h.vert_offsets[v] as usize
                ..h.vert_offsets[v + 1] as usize
            {
                vert_seg[s] = v as u32;
            }
        }

        let (mut prm, labels0) =
            params::init_random(nv, cfg.beta as f32, cfg.seed);
        let mut label_v: Vec<f32> =
            labels0.iter().map(|&l| l as f32).collect();

        let mut em_window = ConvergenceWindow::new(cfg.window, cfg.threshold);
        let mut total_map = 0usize;
        let mut em_iters = 0usize;

        for _em in 0..cfg.em_iters {
            em_iters += 1;
            total_map += cfg.map_iters;
            let out = self
                .runtime
                .em_loop(
                    &y_elem, &label_v, &h.hood_id, &h.members,
                    &h.vert_elems, &vert_seg, nh, cfg.map_iters, &prm,
                )
                .expect("em_loop dispatch failed");
            label_v = out.label_v;

            let stats = Stats {
                acc: [
                    [out.stats[0] as f64, out.stats[1] as f64,
                     out.stats[2] as f64],
                    [out.stats[3] as f64, out.stats[4] as f64,
                     out.stats[5] as f64],
                ],
            };
            prm = params::update(&stats, cfg.beta as f32);
            em_window.push(out.total as f64);
        }

        EmResult {
            labels: label_v.iter().map(|&l| l as u8).collect(),
            em_iters,
            map_iters: total_map,
            energy: *em_window.history().last().unwrap_or(&0.0),
            history: em_window.history().to_vec(),
            params: prm,
            lower_bound: None,
            pmp: None,
            bp: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OversegConfig;
    use crate::dpp::Backend;
    use crate::overseg::oversegment;

    fn small_model(seed: u64) -> MrfModel {
        let v = crate::image::synth::porous_ground_truth(48, 48, 1, 0.42,
                                                         seed);
        let mut input = v.clone();
        crate::image::noise::additive_gaussian(&mut input, 60.0, seed);
        let seg = oversegment(
            &Backend::Serial,
            &input.slice(0),
            &OversegConfig { scale: 64.0, min_region: 4 },
        );
        crate::mrf::build_model_serial(&seg)
    }

    /// `None` (skip) without AOT artifacts / a real PJRT binding —
    /// offline builds use the stub in `rust/src/runtime/xla.rs`.
    fn runtime() -> Option<Arc<EmRuntime>> {
        match EmRuntime::load(std::path::Path::new("artifacts")) {
            Ok(rt) => Some(Arc::new(rt)),
            Err(e) => {
                eprintln!("skipping xla engine test: {e}");
                None
            }
        }
    }

    #[test]
    fn xla_engine_agrees_with_serial() {
        let Some(rt) = runtime() else { return };
        let model = small_model(31);
        let cfg = MrfConfig { fixed_iters: true, em_iters: 3, map_iters: 3,
                              ..Default::default() };
        let want = super::super::serial::SerialEngine.run(&model, &cfg);
        let got = XlaEngine::new(rt).run(&model, &cfg);
        let agree = got
            .labels
            .iter()
            .zip(&want.labels)
            .filter(|(a, b)| a == b)
            .count();
        let frac = agree as f64 / want.labels.len() as f64;
        assert!(frac > 0.995, "agreement {frac}");
        // energies within f32 dispatch tolerance
        for (a, b) in got.history.iter().zip(&want.history) {
            assert!((a - b).abs() < 1e-3 * b.abs().max(1.0),
                    "{a} vs {b}");
        }
        // parameters close
        for l in 0..2 {
            assert!((got.params.mu[l] - want.params.mu[l]).abs() < 0.5);
            assert!((got.params.sigma[l] - want.params.sigma[l]).abs() < 0.5);
        }
    }

    #[test]
    fn fused_loop_path_matches_stepwise_path() {
        // The in-device K-loop must produce the same labels as the
        // per-iteration dispatch path on the same model/config.
        let Some(rt) = runtime() else { return };
        let model = small_model(33);
        let cfg = MrfConfig { fixed_iters: true, em_iters: 3, map_iters: 3,
                              ..Default::default() };
        let fused = XlaEngine::new(Arc::clone(&rt)).run(&model, &cfg);
        // Force the stepwise path by running the same engine in
        // convergence mode with thresholds that never trigger.
        let cfg_step = MrfConfig {
            fixed_iters: false,
            em_iters: 3,
            map_iters: 3,
            threshold: 0.0,
            ..Default::default()
        };
        let step = XlaEngine::new(rt).run(&model, &cfg_step);
        let agree = fused
            .labels
            .iter()
            .zip(&step.labels)
            .filter(|(a, b)| a == b)
            .count();
        let frac = agree as f64 / step.labels.len() as f64;
        assert!(frac > 0.999, "agreement {frac}");
        for (a, b) in fused.history.iter().zip(&step.history) {
            assert!((a - b).abs() < 1e-3 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn xla_engine_convergence_mode() {
        let Some(rt) = runtime() else { return };
        let model = small_model(32);
        let cfg = MrfConfig::default();
        let res = XlaEngine::new(rt).run(&model, &cfg);
        assert!(res.em_iters <= cfg.em_iters);
        assert!(res.labels.iter().all(|&l| l <= 1));
    }
}
