//! k-neighborhood (k=1) construction from maximal cliques (§3.2.1).
//!
//! A neighborhood ("hood") is a maximal clique plus every vertex within
//! one edge of any clique member, deduplicated and sorted by vertex id.
//! The flattened hood-member array is the element domain the whole EM
//! pipeline parallelizes over (the paper's `hoods` array).
//!
//! Two builders: a HashSet-based serial reference, and the paper's
//! DPP pipeline — Map (count neighbors), Scan (allocate), Map (fill),
//! SortByKey + Unique (dedup) — over (hoodId, vertexId) pairs packed
//! into u64 keys.

use std::collections::BTreeSet;

use crate::dpp::{self, Device, DeviceExt};
use crate::graph::Csr;
use crate::mce::CliqueSet;

/// Neighborhood structure + the static index arrays the engines need.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hoods {
    /// Element ranges per hood (`num_hoods + 1` entries).
    pub offsets: Vec<u32>,
    /// Element -> vertex id, hood-major, sorted within each hood.
    pub members: Vec<u32>,
    /// Element -> owning hood id (expansion of `offsets`).
    pub hood_id: Vec<u32>,
    /// Elements grouped by vertex: ranges into `vert_elems`
    /// (`num_vertices + 1` entries).
    pub vert_offsets: Vec<u32>,
    /// Element ids grouped by vertex, ascending within each vertex.
    pub vert_elems: Vec<u32>,
}

impl Hoods {
    pub fn num_hoods(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    pub fn num_elements(&self) -> usize {
        self.members.len()
    }

    pub fn hood_members(&self, h: usize) -> &[u32] {
        &self.members[self.offsets[h] as usize..self.offsets[h + 1] as usize]
    }

    pub fn hood_size(&self, h: usize) -> u32 {
        self.offsets[h + 1] - self.offsets[h]
    }

    /// Distribution of hood sizes (the paper's neighborhood
    /// "demographics", §4.3.3).
    pub fn size_histogram(&self, bin: u32) -> crate::util::Histogram {
        crate::util::Histogram::from_values(
            (0..self.num_hoods()).map(|h| self.hood_size(h)),
            bin,
        )
    }

    /// Derive `hood_id` + per-vertex element grouping from
    /// (offsets, members). Shared by both builders.
    fn finalize(offsets: Vec<u32>, members: Vec<u32>, num_vertices: usize)
        -> Hoods {
        let n = members.len();
        let mut hood_id = vec![0u32; n];
        for h in 0..offsets.len() - 1 {
            for e in offsets[h] as usize..offsets[h + 1] as usize {
                hood_id[e] = h as u32;
            }
        }
        // Counting sort of elements by vertex (stable -> element ids
        // ascend within each vertex).
        let mut counts = vec![0u32; num_vertices + 1];
        for &v in &members {
            counts[v as usize + 1] += 1;
        }
        for i in 0..num_vertices {
            counts[i + 1] += counts[i];
        }
        let vert_offsets = counts.clone();
        let mut vert_elems = vec![0u32; n];
        let mut cursor = counts;
        for (e, &v) in members.iter().enumerate() {
            vert_elems[cursor[v as usize] as usize] = e as u32;
            cursor[v as usize] += 1;
        }
        Hoods { offsets, members, hood_id, vert_offsets, vert_elems }
    }
}

/// Serial reference builder.
pub fn build_serial(g: &Csr, cliques: &CliqueSet, num_vertices: usize)
    -> Hoods {
    let mut offsets = vec![0u32];
    let mut members = Vec::new();
    for c in 0..cliques.num_cliques() {
        let clique = cliques.clique(c);
        let mut set: BTreeSet<u32> = clique.iter().copied().collect();
        for &v in clique {
            set.extend(g.neighbors_of(v).iter().copied());
        }
        members.extend(set.iter().copied());
        offsets.push(members.len() as u32);
    }
    Hoods::finalize(offsets, members, num_vertices)
}

/// DPP builder (paper §3.2.1 steps 1–4).
pub fn build_dpp(bk: &dyn Device, g: &Csr, cliques: &CliqueSet,
                 num_vertices: usize) -> Hoods {
    let nc = cliques.num_cliques();
    if nc == 0 {
        return Hoods::finalize(vec![0], Vec::new(), num_vertices);
    }
    let total_members = cliques.members.len();

    // Step 1 (Map): per clique-member instance, 1 + degree candidate
    // entries (the vertex itself + all its 1-hop neighbors).
    let counts: Vec<u32> = dpp::map_indexed(bk, total_members, |i| {
        1 + g.degree(cliques.members[i]) as u32
    });
    // Step 2 (Scan): output offsets.
    let (offs, total) = dpp::scan_exclusive(bk, &counts, 0u32, |a, b| a + b);

    // Which clique does instance i belong to? Expand clique offsets.
    let mut inst_clique = vec![0u32; total_members];
    for c in 0..nc {
        for i in cliques.offsets[c] as usize..cliques.offsets[c + 1] as usize {
            inst_clique[i] = c as u32;
        }
    }

    // Step 3 (Map): emit (hoodId, vertex) packed pairs.
    let mut pairs = vec![0u64; total as usize];
    {
        let win = crate::dpp::core::SharedSlice::new(&mut pairs);
        let offs_ref = &offs;
        let inst_clique_ref = &inst_clique;
        bk.for_chunks(total_members, |s, e| {
            for i in s..e {
                let c = inst_clique_ref[i];
                let v = cliques.members[i];
                let mut at = offs_ref[i] as usize;
                unsafe { win.write(at, dpp::pack_pair(c, v)) };
                at += 1;
                for &w in g.neighbors_of(v) {
                    unsafe { win.write(at, dpp::pack_pair(c, w)) };
                    at += 1;
                }
            }
        });
    }

    // Step 4: SortByKey (hoodId, vertexId) then Unique.
    dpp::sort_keys(bk, &mut pairs);
    let uniq = dpp::unique(bk, &pairs);

    // CSR-ify: members + offsets per hood. Every clique produces at
    // least its own members, so all hood ids appear.
    let members: Vec<u32> = dpp::map(bk, &uniq, |&k| dpp::unpack_pair(k).1);
    let hood_of: Vec<u32> = dpp::map(bk, &uniq, |&k| dpp::unpack_pair(k).0);
    let starts = dpp::select_indices(bk, hood_of.len(), |i| {
        i == 0 || hood_of[i] != hood_of[i - 1]
    });
    debug_assert_eq!(starts.len(), nc, "every clique forms a hood");
    let mut offsets = starts;
    offsets.push(members.len() as u32);

    Hoods::finalize(offsets, members, num_vertices)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::Backend;
    use crate::mce;
    use crate::pool::Pool;

    fn csr(n: usize, edges: &[(u32, u32)]) -> Csr {
        let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
        for &(a, b) in edges {
            adj[a as usize].push(b);
            adj[b as usize].push(a);
        }
        let mut offsets = vec![0u32];
        let mut neighbors = Vec::new();
        for l in adj.iter_mut() {
            l.sort_unstable();
            l.dedup();
            neighbors.extend_from_slice(l);
            offsets.push(neighbors.len() as u32);
        }
        Csr { offsets, neighbors }
    }

    fn backends() -> Vec<Backend> {
        vec![
            Backend::Serial,
            Backend::threaded_with_grain(Pool::new(4), 32),
        ]
    }

    #[test]
    fn hood_is_clique_plus_one_hop() {
        // path 0-1-2-3 plus triangle 1-2-4
        let g = csr(5, &[(0, 1), (1, 2), (2, 3), (1, 4), (2, 4)]);
        let cliques = mce::enumerate_serial(&g);
        let hoods = build_serial(&g, &cliques, 5);
        // find the hood of clique {1,2,4}: must contain 0 and 3 too
        let idx = (0..cliques.num_cliques())
            .find(|&i| cliques.clique(i) == [1, 2, 4])
            .unwrap();
        assert_eq!(hoods.hood_members(idx), &[0, 1, 2, 3, 4]);
    }

    #[test]
    fn dpp_matches_serial() {
        use crate::util::Pcg32;
        let mut rng = Pcg32::seeded(17);
        for trial in 0..6 {
            let n = 25 + trial * 9;
            let mut edges = Vec::new();
            for _ in 0..n * 2 {
                let a = rng.below(n as u32);
                let b = rng.below(n as u32);
                if a != b {
                    edges.push((a.min(b), a.max(b)));
                }
            }
            let g = csr(n, &edges);
            let cliques = mce::enumerate_serial(&g);
            let want = build_serial(&g, &cliques, n);
            for bk in backends() {
                let got = build_dpp(&bk, &g, &cliques, n);
                assert_eq!(got, want, "trial {trial}");
            }
        }
    }

    #[test]
    fn members_sorted_within_hood() {
        let g = csr(6, &[(0, 1), (1, 2), (0, 2), (2, 3), (3, 4), (4, 5)]);
        let cliques = mce::enumerate_serial(&g);
        let hoods = build_serial(&g, &cliques, 6);
        for h in 0..hoods.num_hoods() {
            let m = hoods.hood_members(h);
            assert!(m.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn vertex_grouping_is_inverse_of_members() {
        let g = csr(6, &[(0, 1), (1, 2), (0, 2), (2, 3), (3, 4), (4, 5)]);
        let cliques = mce::enumerate_serial(&g);
        let hoods = build_serial(&g, &cliques, 6);
        // every element appears exactly once in vert_elems
        let mut seen = vec![false; hoods.num_elements()];
        for v in 0..6 {
            for &e in &hoods.vert_elems[hoods.vert_offsets[v] as usize
                ..hoods.vert_offsets[v + 1] as usize]
            {
                assert_eq!(hoods.members[e as usize], v as u32);
                assert!(!seen[e as usize]);
                seen[e as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn hood_id_expands_offsets() {
        let g = csr(4, &[(0, 1), (2, 3)]);
        let cliques = mce::enumerate_serial(&g);
        let hoods = build_serial(&g, &cliques, 4);
        for h in 0..hoods.num_hoods() {
            for e in hoods.offsets[h] as usize..hoods.offsets[h + 1] as usize {
                assert_eq!(hoods.hood_id[e], h as u32);
            }
        }
    }
}
