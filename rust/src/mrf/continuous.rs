//! Continuous-label MRF: Gaussian data term + truncated-quadratic
//! smoothness (DESIGN.md §14).
//!
//! The discrete engines optimize a Potts model over a fixed label set;
//! the particle max-product engine ([`crate::pmp`]) optimizes over
//! **continuous** per-vertex labels `x_v ∈ ℝ`:
//!
//! ```text
//! E(x) = Σ_v ((x_v − y_v) / σ)²/2
//!      + Σ_{(u,v) ∈ E} λ · min(((x_u − x_v)/σ)², τ²)
//! ```
//!
//! The data term pulls each vertex toward its observation; the
//! truncated quadratic smooths neighbors while letting true
//! discontinuities pay a bounded penalty (the classic
//! discontinuity-preserving denoising prior). Both terms are exposed
//! as `#[inline]` per-item kernels so the serial oracle and the DPP
//! path of `pmp::solve` evaluate *the same* f32 expressions — the
//! bitwise-identity discipline every engine family in this repo
//! follows.

use crate::graph::Csr;

/// A continuous-label MRF instance over an undirected [`Csr`] graph.
///
/// Invariants: `y.len() == graph.num_vertices()`; neighbor lists are
/// symmetric (every directed edge has its reverse), as produced by
/// [`grid_graph`] or the RAG builders.
#[derive(Debug, Clone)]
pub struct ContinuousModel {
    pub graph: Csr,
    /// Observation per vertex (the noisy signal).
    pub y: Vec<f32>,
    /// Gaussian data/smoothness scale σ (> 0).
    pub sigma: f32,
    /// Smoothness weight λ (≥ 0).
    pub lambda: f32,
    /// Truncation point τ of the pair term, in units of σ.
    pub trunc: f32,
}

impl ContinuousModel {
    pub fn new(
        graph: Csr,
        y: Vec<f32>,
        sigma: f32,
        lambda: f32,
        trunc: f32,
    ) -> ContinuousModel {
        assert_eq!(y.len(), graph.num_vertices(), "y per vertex");
        assert!(sigma > 0.0 && sigma.is_finite(), "sigma {sigma}");
        assert!(lambda >= 0.0 && lambda.is_finite(), "lambda {lambda}");
        assert!(trunc >= 0.0 && trunc.is_finite(), "trunc {trunc}");
        ContinuousModel { graph, y, sigma, lambda, trunc }
    }

    pub fn num_vertices(&self) -> usize {
        self.graph.num_vertices()
    }

    /// Data energy of placing label `x` at vertex `v`:
    /// `((x − y_v)/σ)² / 2`. Shared per-item kernel.
    #[inline]
    pub fn data_energy(&self, v: usize, x: f32) -> f32 {
        let d = (x - self.y[v]) / self.sigma;
        0.5 * d * d
    }

    /// Pair energy of neighboring labels `a`, `b`:
    /// `λ · min(((a−b)/σ)², τ²)`. Shared per-item kernel.
    #[inline]
    pub fn pair_energy(&self, a: f32, b: f32) -> f32 {
        let d = (a - b) / self.sigma;
        let q = d * d;
        let t = self.trunc * self.trunc;
        self.lambda * if q < t { q } else { t }
    }

    /// Total energy of a full labeling, in f64, in a fixed serial
    /// order (vertices ascending; each undirected edge once, from its
    /// lower endpoint). Both `pmp` paths score candidates through this
    /// one accumulation, so their energies agree bitwise.
    pub fn energy(&self, x: &[f32]) -> f64 {
        assert_eq!(x.len(), self.num_vertices());
        let mut total = 0.0f64;
        for v in 0..self.num_vertices() {
            total += self.data_energy(v, x[v]) as f64;
        }
        for v in 0..self.num_vertices() {
            let (s, e) = (
                self.graph.offsets[v] as usize,
                self.graph.offsets[v + 1] as usize,
            );
            for &u in &self.graph.neighbors[s..e] {
                if (u as usize) > v {
                    total +=
                        self.pair_energy(x[v], x[u as usize]) as f64;
                }
            }
        }
        total
    }
}

/// 4-connected `w × h` grid as a symmetric CSR — the denoising
/// example's pixel graph (each pixel is a vertex; no
/// oversegmentation).
pub fn grid_graph(w: usize, h: usize) -> Csr {
    let nv = w * h;
    let mut offsets = Vec::with_capacity(nv + 1);
    let mut neighbors = Vec::new();
    offsets.push(0u32);
    for r in 0..h {
        for c in 0..w {
            // Ascending vertex ids keep rows sorted.
            if r > 0 {
                neighbors.push(((r - 1) * w + c) as u32);
            }
            if c > 0 {
                neighbors.push((r * w + c - 1) as u32);
            }
            if c + 1 < w {
                neighbors.push((r * w + c + 1) as u32);
            }
            if r + 1 < h {
                neighbors.push(((r + 1) * w + c) as u32);
            }
            offsets.push(neighbors.len() as u32);
        }
    }
    Csr { offsets, neighbors }
}

/// Synthetic denoising instance: a piecewise-constant step image
/// (two plateaus at 60 / 180, like the Potts fixtures) plus seeded
/// Gaussian noise. Returns `(model, clean)` so callers can measure
/// reconstruction error against ground truth.
pub fn synthetic_denoise(
    w: usize,
    h: usize,
    noise_sigma: f32,
    seed: u64,
) -> (ContinuousModel, Vec<f32>) {
    let mut rng = crate::util::Pcg32::seeded(seed);
    let nv = w * h;
    let mut clean = Vec::with_capacity(nv);
    for r in 0..h {
        for c in 0..w {
            // A step edge down the middle plus a bright block in one
            // quadrant: plateaus with genuine discontinuities.
            let base = if c < w / 2 { 60.0f32 } else { 180.0 };
            let block = r < h / 2 && c >= w / 4 && c < w / 2;
            clean.push(if block { 180.0 } else { base });
        }
    }
    let y: Vec<f32> = clean
        .iter()
        .map(|&v| v + noise_sigma * rng.normal() as f32)
        .collect();
    let model = ContinuousModel::new(
        grid_graph(w, h),
        y,
        noise_sigma.max(1.0),
        0.5,
        4.0,
    );
    (model, clean)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_graph_is_symmetric_and_sorted() {
        let g = grid_graph(3, 2);
        assert_eq!(g.num_vertices(), 6);
        for v in 0..6u32 {
            let row = g.neighbors_of(v);
            assert!(row.windows(2).all(|w| w[0] < w[1]), "row sorted");
            for &u in row {
                assert!(g.neighbors_of(u).contains(&v), "symmetric");
            }
        }
        // Interior corner checks: vertex 0 has right + down.
        assert_eq!(g.neighbors_of(0), &[1, 3]);
        assert_eq!(g.neighbors_of(4), &[1, 3, 5]);
    }

    #[test]
    fn pair_term_truncates() {
        let m = ContinuousModel::new(
            grid_graph(2, 1),
            vec![0.0, 0.0],
            10.0,
            2.0,
            3.0,
        );
        // Below truncation: quadratic.
        assert_eq!(m.pair_energy(0.0, 10.0), 2.0);
        // Far above truncation: capped at λ·τ².
        assert_eq!(m.pair_energy(0.0, 1000.0), 2.0 * 9.0);
    }

    #[test]
    fn energy_counts_each_edge_once() {
        let m = ContinuousModel::new(
            grid_graph(2, 1),
            vec![1.0, 5.0],
            1.0,
            1.0,
            100.0,
        );
        let x = [1.0f32, 2.0];
        // data: 0 + 0.5·9; pair: 1·1² once.
        let want = 0.5 * 9.0 + 1.0;
        assert!((m.energy(&x) - want).abs() < 1e-12);
    }
}
