//! DPP-PMRF engine — the paper's contribution (Alg. 2, §3.2.2).
//!
//! Every step of the EM/MAP optimization is a composition of the
//! [`crate::dpp`] primitives over flat element arrays:
//!
//! 1. **Gather** current labels to elements.
//! 2. **ReduceByKey⟨Add⟩** per-hood label-1 counts; **Gather** back.
//! 3. **Map** the energy function over the label-replicated element
//!    array (2n entries: label-0 copies then label-1 copies — the
//!    paper's `testLabel`/`oldIndex` layout, with the replication
//!    simulated by index arithmetic instead of materialized, as in the
//!    paper's "memory-free Gather").
//! 4. **SortByKey** replicated energies by element id to pair the two
//!    label copies, then **ReduceByKey⟨Min⟩** for per-vertex-instance
//!    minima (paper mode). The *planned* mode caches that sort in a
//!    [`crate::dpp::SegmentPlan`] built once per run and executes each
//!    iteration as one fused [`crate::dpp::Pipeline`] region
//!    (`benches/ablation_fusion.rs`); the *fused* mode goes further
//!    and computes both energies and the min in one Map — the
//!    L1-kernel layout — skipping the pairing pass entirely
//!    (`benches/ablation_sort.rs`).
//! 5. **Gather + ReduceByKey⟨Min⟩** over the static by-vertex grouping
//!    to resolve each vertex's label (deterministic tie-break).
//! 6. **ReduceByKey⟨Add⟩** per-hood energy sums; **Map/Reduce** for the
//!    convergence windows; **Scatter** labels back.
//! 7. Per-label parameter statistics via chunked **Reduce**.

//! Allocation discipline — deny(hot-loop-alloc): every `map_iter` is
//! steady-state allocation-free. Per-iteration tensors are drawn from
//! the engine's [`Workspace`] (one per engine, and therefore one per
//! scheduler lane) through the `_into`/`_ws` primitives; allocations
//! below are annotated `alloc-ok` (once-per-run setup) and checked by
//! `ci/check_hot_loop_allocs.sh` + `benches/alloc_churn.rs`.

use std::sync::Arc;

use crate::config::MrfConfig;
use crate::dpp::{self, Device, DeviceExt, IntoDevice, Workspace,
                 WorkspaceStats};

use super::energy::{self, Params};
use super::params::{self, Stats};
use super::{ConvergenceWindow, Engine, EmResult, HoodWindows, MrfModel};

/// Label-pairing strategy for step 4.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PairMode {
    /// Paper-literal §3.2.2 pipeline: replicate energies (2n),
    /// SortByKey by element, `ReduceByKey<Min>` — one fork-join and
    /// one full sort **per iteration**. Kept as the unfused baseline:
    /// the
    /// per-DPP breakdown (§4.3.2) reproduces on it and
    /// `benches/ablation_fusion.rs` measures against it.
    Paper,
    /// The paper's exact DPP composition, restructured around static
    /// graph structure: every segmentation (hood membership, vertex
    /// grouping, the §3.2.2 pairing keys) becomes a
    /// [`crate::dpp::SegmentPlan`] built **once per run** — the sort
    /// the paper pays per iteration is paid once — and each MAP
    /// iteration executes as **one** [`crate::dpp::Pipeline`] region
    /// (a phase barrier per stage instead of a fork-join per
    /// primitive). Bitwise-identical results to Paper mode.
    Planned,
    /// Default (§Perf result): fused energy+min Map — the exact layout
    /// the L1 Pallas kernel uses — over *static* hood/vertex segments,
    /// with a preallocated workspace (no per-iteration allocation, no
    /// sort). Bitwise-identical results to Paper mode.
    #[default]
    Fused,
}

pub struct DppEngine {
    device: Arc<dyn Device>,
    pub mode: PairMode,
    /// Scratch pool shared by every run of this engine: per-iteration
    /// tensors and primitive internals are drawn from it, so steady
    /// state allocates nothing and — under [`crate::sched`] — each
    /// optimize lane's engine amortizes buffers across its slices.
    ws: Workspace,
}

impl DppEngine {
    /// Engine on any device — accepts a concrete device, an
    /// `Arc<dyn Device>`, or the deprecated `Backend` spelling.
    pub fn new(device: impl IntoDevice) -> Self {
        DppEngine {
            device: device.into_device(),
            mode: PairMode::default(),
            ws: Workspace::new(),
        }
    }

    pub fn with_mode(device: impl IntoDevice, mode: PairMode) -> Self {
        DppEngine { device: device.into_device(), mode,
                    ws: Workspace::new() }
    }

    /// The device every primitive of this engine executes on.
    pub fn device(&self) -> &Arc<dyn Device> {
        &self.device
    }

    /// Counters of the engine-held scratch pool — after one warm-up
    /// iteration the hit rate stays at 100% for the rest of the run
    /// (pinned by `tests/workspace_reuse.rs`).
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::SerialDevice;
    /// use dpp_pmrf::mrf::dpp::DppEngine;
    /// let engine = DppEngine::new(SerialDevice);
    /// assert_eq!(engine.workspace_stats().hits, 0); // nothing run yet
    /// ```
    pub fn workspace_stats(&self) -> WorkspaceStats {
        self.ws.stats()
    }
}

impl Engine for DppEngine {
    fn name(&self) -> &'static str {
        match self.mode {
            PairMode::Paper => "dpp-paper",
            PairMode::Planned => "dpp-planned",
            PairMode::Fused => "dpp",
        }
    }

    fn run(&self, model: &MrfModel, cfg: &MrfConfig) -> EmResult {
        let nh = model.hoods.num_hoods();
        let bk: &dyn Device = &*self.device;
        let res = match self.mode {
            PairMode::Paper => {
                let (mut step, prm) =
                    PaperStep::new(bk, &self.ws, model, cfg);
                drive_em(&mut step, nh, prm, cfg)
            }
            PairMode::Planned => {
                let (mut step, prm) =
                    PlannedStep::new(bk, &self.ws, model, cfg);
                drive_em(&mut step, nh, prm, cfg)
            }
            PairMode::Fused => {
                let (mut step, prm) =
                    FusedStep::new(bk, &self.ws, model, cfg);
                drive_em(&mut step, nh, prm, cfg)
            }
        };
        self.ws.publish_timing();
        res
    }
}

/// One mode's per-iteration behavior, driven by [`drive_em`]. The
/// trait splits exactly along the seams the three modes differ on;
/// everything else (EM/MAP loop structure, convergence windows,
/// parameter re-estimation cadence) lives once in the driver.
trait EmStep {
    /// One MAP (Jacobi) iteration under `prm`; leaves this iteration's
    /// per-hood energies in `hood_energy`.
    fn map_iter(&mut self, prm: &Params, hood_energy: &mut [f64]);
    /// Per-label statistics of the latest instance-argmin labels (the
    /// EM M-step input).
    fn stats(&mut self) -> Stats;
    /// Count vertices whose label changed since the last call
    /// (flight-recorder input; only called on armed runs — the first
    /// call seeds `delta` and reports 0).
    fn labels_changed(&mut self, delta: &mut crate::obs::LabelDelta)
        -> u64;
    /// Final per-vertex labels (consumes the step's label state).
    fn take_labels(&mut self) -> Vec<u8>;
}

/// The single EM outer-loop driver all [`PairMode`]s share (ROADMAP
/// item): MAP-iterate until every hood's windowed energy converges (or
/// `map_iters`), re-estimate parameters, repeat until the total energy
/// converges (or `em_iters`). Identical control flow — and therefore
/// bitwise-identical energy traces per mode — to the three drivers it
/// replaced.
fn drive_em(
    step: &mut dyn EmStep,
    nh: usize,
    mut prm: Params,
    cfg: &MrfConfig,
) -> EmResult {
    let mut hood_energy = vec![0.0f64; nh]; // alloc-ok: once per run
    let mut em_window = ConvergenceWindow::new(cfg.window, cfg.threshold);
    let mut total_map = 0usize;
    let mut em_iters = 0usize;
    // Hoisted out of the EM loop (reset per iteration) so EM
    // iterations allocate nothing after the first.
    let mut hw = HoodWindows::new(nh, cfg.window, cfg.threshold);
    // Flight-recorder state: armed runs seed the labels-changed
    // counter once here so every in-loop sample reports a true delta;
    // disarmed runs never touch it (zero-alloc contract intact).
    let mut delta = crate::obs::LabelDelta::new();
    if crate::obs::armed() {
        step.labels_changed(&mut delta);
    }

    for _em in 0..cfg.em_iters {
        // Inert (no clock read, no allocation) unless a tracer is
        // armed — the telemetry-off MAP loop stays zero-alloc.
        let _em_span = crate::telemetry::span_arg(
            "em", "em_iter", "iter", em_iters as u64,
        );
        em_iters += 1;
        hw.reset();
        for _map in 0..cfg.map_iters {
            let _map_span = crate::telemetry::span_arg(
                "map", "map_iter", "iter", total_map as u64,
            );
            total_map += 1;
            step.map_iter(&prm, &mut hood_energy);
            // Flight-recorder hook (DESIGN.md §13): one relaxed load
            // when off; the energy sum and label diff are only paid
            // on armed runs.
            if crate::obs::live() {
                if crate::obs::armed() {
                    let changed = step.labels_changed(&mut delta);
                    let energy: f64 = hood_energy.iter().sum();
                    crate::obs::map_sample(
                        em_iters - 1, total_map - 1, energy, changed,
                    );
                } else {
                    crate::obs::tick();
                }
            }
            let done = hw.push_all(&hood_energy);
            if done && !cfg.fixed_iters {
                break;
            }
        }

        let stats = step.stats();
        prm = params::update(&stats, cfg.beta as f32);

        let total: f64 = hood_energy.iter().sum();
        em_window.push(total);
        if em_window.converged() && !cfg.fixed_iters {
            break;
        }
    }

    EmResult {
        labels: step.take_labels(),
        em_iters,
        map_iters: total_map,
        energy: *em_window.history().last().unwrap_or(&0.0),
        history: em_window.history().to_vec(), // alloc-ok: once per run
        params: prm,
        lower_bound: None,
        pmp: None,
        bp: None,
    }
}

/// Paper-literal pipeline built from the generic primitives (one
/// fork-join and one full sort per iteration — the unfused baseline).
/// Since ISSUE 5 every per-iteration tensor is drawn from the
/// engine's [`Workspace`] through the `_into`/`_ws` primitives, so a
/// steady-state iteration allocates nothing while computing exactly
/// the values (and float op orders) the allocating spelling did.
struct PaperStep<'a> {
    bk: &'a dyn Device,
    ws: &'a Workspace,
    model: &'a MrfModel,
    n: usize,
    // ---- static arrays (built once; Alg. 2 lines 1–5) ----
    y_elem: Vec<f32>,
    size_e: Vec<f32>,
    /// Vertex grouping for step 5: keys (grouped by construction).
    vert_keys: Vec<u32>,
    /// Distinct vertices appearing in hoods (scatter targets of step
    /// 5) — static, computed once; the old code re-derived it every
    /// iteration from the equally static `vert_keys`.
    touched: Vec<u32>,
    labels: Vec<f32>,
    amin: Vec<u8>,
}

impl<'a> PaperStep<'a> {
    fn new(
        bk: &'a dyn Device,
        ws: &'a Workspace,
        model: &'a MrfModel,
        cfg: &MrfConfig,
    ) -> (PaperStep<'a>, Params) {
        let h = &model.hoods;
        let n = h.num_elements();
        let nh = h.num_hoods();
        let nv = model.num_vertices();

        // alloc-ok: once-per-run static arrays (Alg. 2 lines 1–5).
        let y_elem: Vec<f32> = dpp::gather(bk, &model.y, &h.members);
        let size_h: Vec<f32> =
            dpp::map_indexed(bk, nh, |i| h.hood_size(i) as f32);
        let size_e: Vec<f32> = dpp::gather(bk, &size_h, &h.hood_id);
        let vert_keys: Vec<u32> = dpp::map_indexed(bk, n, |i| {
            h.members[h.vert_elems[i] as usize]
        });
        let touched = dpp::unique(bk, &vert_keys); // alloc-ok: once

        let (prm, labels_u8) =
            params::init_random(nv, cfg.beta as f32, cfg.seed);
        let labels: Vec<f32> = dpp::map(bk, &labels_u8, |&l| l as f32);

        (
            PaperStep {
                bk,
                ws,
                model,
                n,
                y_elem,
                size_e,
                vert_keys,
                touched,
                labels,
                amin: vec![0u8; n], // alloc-ok: once per run
            },
            prm,
        )
    }
}

impl EmStep for PaperStep<'_> {
    fn map_iter(&mut self, prm: &Params, hood_energy: &mut [f64]) {
        let bk = self.bk;
        let ws = self.ws;
        let h = &self.model.hoods;
        let n = self.n;

        // (1) Gather labels to elements.
        let mut lbl_e = ws.take_spare::<f32>(n);
        dpp::gather_into(bk, &self.labels, &h.members, &mut lbl_e);
        // (2) Per-hood label-1 counts; gather back to elements.
        let nh = h.num_hoods();
        let mut ones_keys = ws.take_spare::<u32>(nh);
        let mut ones_h = ws.take_spare::<f32>(nh);
        dpp::reduce_by_key_into(
            bk, ws, &h.hood_id, &lbl_e[..], 0.0f32, |a, b| a + b,
            &mut ones_keys, &mut ones_h,
        );
        let mut ones_e = ws.take_spare::<f32>(n);
        dpp::gather_into(bk, &ones_h[..], &h.hood_id, &mut ones_e);

        // (3)+(4) energies and per-instance minima.
        let mut e_min = ws.take_spare::<f32>(n);
        pair_paper(
            bk, ws, n, &self.y_elem, &lbl_e[..], &ones_e[..],
            &self.size_e, prm, &mut e_min, &mut self.amin,
        );

        // (5) Per-vertex resolution over the static grouping.
        let mut packed = ws.take_spare::<u64>(n);
        dpp::zip_map_into(
            bk, &e_min[..], &self.amin,
            |&e, &a| energy::pack_energy_label(e, a), &mut packed,
        );
        let mut packed_by_vert = ws.take_spare::<u64>(h.vert_elems.len());
        dpp::gather_into(bk, &packed[..], &h.vert_elems,
                         &mut packed_by_vert);
        let mut best_keys = ws.take_spare::<u32>(self.touched.len());
        let mut best = ws.take_spare::<u64>(self.touched.len());
        dpp::reduce_by_key_into(
            bk, ws, &self.vert_keys, &packed_by_vert[..], u64::MAX,
            |a, b| a.min(b), &mut best_keys, &mut best,
        );
        // Scatter resolved labels back to the vertex array.
        // (vert_keys is ascending-grouped and covers exactly the
        // vertices that appear in hoods — self.touched.)
        let mut resolved = ws.take_spare::<f32>(best.len());
        dpp::map_into(bk, &best[..],
                      |&p| energy::unpack_label(p) as f32, &mut resolved);
        dpp::scatter(bk, &resolved[..], &self.touched, &mut self.labels);

        // (6) Per-hood energy sums.
        let mut emin_f64 = ws.take_spare::<f64>(n);
        dpp::map_into(bk, &e_min[..], |&e| e as f64, &mut emin_f64);
        let mut he_keys = ws.take_spare::<u32>(nh);
        let mut he = ws.take_spare::<f64>(nh);
        dpp::reduce_by_key_into(
            bk, ws, &h.hood_id, &emin_f64[..], 0.0f64, |a, b| a + b,
            &mut he_keys, &mut he,
        );
        hood_energy.copy_from_slice(&he);
    }

    /// (7) Parameter statistics (chunked Reduce in chunk order).
    fn stats(&mut self) -> Stats {
        stats_reduce(self.bk, self.ws, &self.amin, &self.y_elem)
    }

    fn labels_changed(&mut self, delta: &mut crate::obs::LabelDelta)
        -> u64 {
        delta.update_f32(&self.labels)
    }

    fn take_labels(&mut self) -> Vec<u8> {
        dpp::map(self.bk, &self.labels, |&l| l as u8) // alloc-ok: once
    }
}

/// Paper-mode pairing: replicated energy Map over 2n, SortByKey by
/// element id, `ReduceByKey<Min>` (§3.2.2 steps 2–3) — all scratch
/// (including the sort's ping-pong buffers) from the workspace,
/// results written into `emin`/`amin`.
#[allow(clippy::too_many_arguments)]
fn pair_paper(
    bk: &dyn Device,
    ws: &Workspace,
    n: usize,
    y: &[f32],
    lbl: &[f32],
    ones: &[f32],
    size: &[f32],
    prm: &Params,
    emin: &mut Vec<f32>,
    amin: &mut Vec<u8>,
) {
    // Replicated energies: i < n -> label 0 copy; i >= n -> label 1.
    // The oldIndex back-gather is index arithmetic (i % n) — the
    // paper's memory-free Gather.
    let pp = energy::Prepared::from_params(prm);
    let mut e_rep = ws.take_spare::<f32>(2 * n);
    dpp::map_indexed_into(bk, 2 * n, |i| {
        let e = i % n;
        let (e0, e1) =
            energy::energy_pair_p(y[e], lbl[e], ones[e], size[e], &pp);
        if i < n { e0 } else { e1 }
    }, &mut e_rep);
    // SortByKey: key = element id, payload = replicated index. The
    // radix sort is stable, so the label-0 copy stays first per key.
    let mut keys = ws.take_spare::<u64>(2 * n);
    dpp::map_indexed_into(bk, 2 * n, |i| (i % n) as u64, &mut keys);
    let mut vals = ws.take_spare::<u32>(2 * n);
    dpp::iota_into(bk, 2 * n, &mut vals);
    dpp::sort_by_key_ws(bk, ws, &mut keys, &mut vals);
    // ReduceByKey<Min-by-energy>: strict '<' keeps the first (label 0)
    // copy on ties, matching the kernel's tie-break.
    let e_rep_ref = &e_rep;
    let mut win_keys = ws.take_spare::<u64>(n);
    let mut win = ws.take_spare::<u32>(n);
    dpp::reduce_by_key_into(
        bk, ws, &keys[..], &vals[..], u32::MAX,
        |a, b| {
            if a == u32::MAX {
                return b;
            }
            if b == u32::MAX {
                return a;
            }
            if e_rep_ref[b as usize] < e_rep_ref[a as usize] { b } else { a }
        },
        &mut win_keys, &mut win,
    );
    dpp::map_into(bk, &win[..], |&i| e_rep[i as usize], emin);
    dpp::map_into(bk, &win[..], |&i| u8::from(i as usize >= n), amin);
}

/// Plan-cached pipeline mode (see [`PairMode::Planned`]): the
/// paper's Alg. 2 step for step, but restructured around what is
/// *static* across EM/MAP iterations.
///
/// Once per run ([`PlannedStep::new`]): build the three
/// [`crate::dpp::SegmentPlan`]s — hood membership and vertex grouping
/// straight from their CSR offsets (segments for free, no sort, empty
/// segments included), and the §3.2.2 replication-pairing keys (the
/// ONE SortByKey of the whole run; the paper re-sorts these identical
/// keys every iteration).
///
/// Per MAP iteration: seven stages — Gather, ReduceByKey⟨Add⟩,
/// Gather, Map, ReduceByKey⟨Min⟩ (pairing), ReduceByKey⟨Min⟩ +
/// scatter (vertex resolve), ReduceByKey⟨Add⟩ (hood energies) — run
/// as **one** [`crate::dpp::Pipeline`] region over a preallocated
/// workspace: one pool entry and six phase barriers instead of ~eight
/// fork-joins, zero per-iteration allocation, no sort.
///
/// Bitwise-identical to Paper mode on every backend: each segment is
/// reduced serially in the cached stable-sort order, which is exactly
/// the order the per-iteration sort would have produced.
struct PlannedStep<'a> {
    bk: &'a dyn Device,
    ws: &'a Workspace,
    model: &'a MrfModel,
    n: usize,
    nh: usize,
    nv: usize,
    y_elem: Vec<f32>,
    size_e: Vec<f32>,
    hood_plan: crate::dpp::SegmentPlan,
    vert_plan: crate::dpp::SegmentPlan,
    pair_plan: crate::dpp::SegmentPlan,
    labels: Vec<u8>,
    // Persistent iteration tensors (allocated once per run; the
    // engine's `Workspace` additionally serves the M-step scratch).
    lbl_e: Vec<f32>,
    ones_h: Vec<f32>,
    ones_e: Vec<f32>,
    e_rep: Vec<f32>,
    emin: Vec<f32>,
    amin: Vec<u8>,
    packed: Vec<u64>,
}

impl<'a> PlannedStep<'a> {
    fn new(
        bk: &'a dyn Device,
        ws: &'a Workspace,
        model: &'a MrfModel,
        cfg: &MrfConfig,
    ) -> (PlannedStep<'a>, Params) {
        use crate::dpp::SegmentPlan;

        let h = &model.hoods;
        let n = h.num_elements();
        let nh = h.num_hoods();
        let nv = model.num_vertices();

        // ---- static arrays + plans (Alg. 2 lines 1–5, plus the
        // sort amortization) ----
        let y_elem: Vec<f32> = dpp::gather(bk, &model.y, &h.members);
        let size_h: Vec<f32> =
            dpp::map_indexed(bk, nh, |i| h.hood_size(i) as f32);
        let size_e: Vec<f32> = dpp::gather(bk, &size_h, &h.hood_id);

        // Hood segments come for free from the CSR offsets (and that
        // form alone stays correct if a hood were ever empty).
        let hood_plan = SegmentPlan::from_csr_offsets(&h.offsets);
        debug_assert_eq!(hood_plan.num_segments(), nh);
        let vert_plan = SegmentPlan::from_csr_offsets(&h.vert_offsets);
        // Pairing keys of §3.2.2: element id of each of the 2n
        // replicated energies. Unsorted, so this build performs the
        // run's single SortByKey.
        let pair_keys: Vec<u64> =
            dpp::map_indexed(bk, 2 * n, |i| (i % n) as u64);
        let pair_plan = SegmentPlan::build(bk, &pair_keys);
        debug_assert_eq!(pair_plan.num_segments(), n);

        let (prm, labels) =
            params::init_random(nv, cfg.beta as f32, cfg.seed);

        (
            PlannedStep {
                bk,
                ws,
                model,
                n,
                nh,
                nv,
                y_elem,
                size_e,
                hood_plan,
                vert_plan,
                pair_plan,
                labels,
                // Once-per-run workspace tensors.
                lbl_e: vec![0.0f32; n],     // alloc-ok: once per run
                ones_h: vec![0.0f32; nh],   // alloc-ok: once per run
                ones_e: vec![0.0f32; n],    // alloc-ok: once per run
                e_rep: vec![0.0f32; 2 * n], // alloc-ok: once per run
                emin: vec![0.0f32; n],      // alloc-ok: once per run
                amin: vec![0u8; n],         // alloc-ok: once per run
                packed: vec![0u64; n],      // alloc-ok: once per run
            },
            prm,
        )
    }
}

impl EmStep for PlannedStep<'_> {
    fn map_iter(&mut self, prm: &Params, hood_energy: &mut [f64]) {
        use crate::dpp::{Pipeline, SharedSlice};

        let bk = self.bk;
        let h = &self.model.hoods;
        let (n, nh, nv) = (self.n, self.nh, self.nv);
        let pp = energy::Prepared::from_params(prm);
        {
            let w_labels = SharedSlice::new(&mut self.labels);
            let w_lbl_e = SharedSlice::new(&mut self.lbl_e);
            let w_ones_h = SharedSlice::new(&mut self.ones_h);
            let w_ones_e = SharedSlice::new(&mut self.ones_e);
            let w_e_rep = SharedSlice::new(&mut self.e_rep);
            let w_emin = SharedSlice::new(&mut self.emin);
            let w_amin = SharedSlice::new(&mut self.amin);
            let w_packed = SharedSlice::new(&mut self.packed);
            let w_he = SharedSlice::new(hood_energy);
            let members = &h.members;
            let hood_id = &h.hood_id;
            let vert_elems = &h.vert_elems;
            let y_ref = &self.y_elem;
            let size_ref = &self.size_e;
            let pp_ref = &pp;
            let hood_plan_ref = &self.hood_plan;
            let vert_plan_ref = &self.vert_plan;
            let pair_plan_ref = &self.pair_plan;
            Pipeline::new()
                        // (1) Gather labels to elements.
                        .stage("Gather", n, |s, e| {
                            for i in s..e {
                                let l = unsafe {
                                    w_labels.read(members[i] as usize)
                                };
                                unsafe { w_lbl_e.write(i, f32::from(l)) };
                            }
                        })
                        // (2) Per-hood label-1 counts over the cached
                        // hood segments.
                        .stage("ReduceByKey", nh, |s, e| {
                            for hd in s..e {
                                let ones = hood_plan_ref.reduce_segment(
                                    hd,
                                    |i| unsafe { w_lbl_e.read(i) },
                                    0.0f32,
                                    |a, b| a + b,
                                );
                                unsafe { w_ones_h.write(hd, ones) };
                            }
                        })
                        // (3) Gather counts back to elements.
                        .stage("Gather", n, |s, e| {
                            for i in s..e {
                                let o = unsafe {
                                    w_ones_h.read(hood_id[i] as usize)
                                };
                                unsafe { w_ones_e.write(i, o) };
                            }
                        })
                        // (4) Replicated energies over 2n (the
                        // memory-free Gather: oldIndex = i % n).
                        .stage("Map", 2 * n, |s, e| {
                            for i in s..e {
                                let el = i % n;
                                let (e0, e1) = energy::energy_pair_p(
                                    y_ref[el],
                                    unsafe { w_lbl_e.read(el) },
                                    unsafe { w_ones_e.read(el) },
                                    size_ref[el],
                                    pp_ref,
                                );
                                let v = if i < n { e0 } else { e1 };
                                unsafe { w_e_rep.write(i, v) };
                            }
                        })
                        // (5) Per-element winner over the cached
                        // pairing segments — the paper's per-iteration
                        // SortByKey + ReduceByKey<Min>, served
                        // sort-free. Strict '<' keeps the label-0 copy
                        // on ties (the plan's stable order puts it
                        // first), matching the kernel's tie-break.
                        .stage("ReduceByKey", n, |s, e| {
                            for el in s..e {
                                let win = pair_plan_ref.reduce_segment(
                                    el,
                                    |i| i as u32,
                                    u32::MAX,
                                    |a, b| {
                                        if a == u32::MAX {
                                            return b;
                                        }
                                        if b == u32::MAX {
                                            return a;
                                        }
                                        let ea = unsafe {
                                            w_e_rep.read(a as usize)
                                        };
                                        let eb = unsafe {
                                            w_e_rep.read(b as usize)
                                        };
                                        if eb < ea { b } else { a }
                                    },
                                );
                                let em =
                                    unsafe { w_e_rep.read(win as usize) };
                                let am = u8::from(win as usize >= n);
                                unsafe {
                                    w_emin.write(el, em);
                                    w_amin.write(el, am);
                                    w_packed.write(
                                        el,
                                        energy::pack_energy_label(em, am),
                                    );
                                }
                            }
                        })
                        // (6) Vertex resolution + label scatter, fused
                        // over the CSR vertex segments (empty segment
                        // = vertex outside every hood: keep label).
                        .stage("ReduceByKey", nv, |s, e| {
                            for v in s..e {
                                if vert_plan_ref.segment_len(v) == 0 {
                                    continue;
                                }
                                let best = vert_plan_ref.reduce_segment(
                                    v,
                                    |i| unsafe {
                                        w_packed
                                            .read(vert_elems[i] as usize)
                                    },
                                    u64::MAX,
                                    |a, b| a.min(b),
                                );
                                unsafe {
                                    w_labels.write(
                                        v,
                                        energy::unpack_label(best),
                                    )
                                };
                            }
                        })
                        // (7) Per-hood energy sums.
                        .stage("ReduceByKey", nh, |s, e| {
                            for hd in s..e {
                                let sum = hood_plan_ref.reduce_segment(
                                    hd,
                                    |i| {
                                        f64::from(unsafe {
                                            w_emin.read(i)
                                        })
                                    },
                                    0.0f64,
                                    |a, b| a + b,
                                );
                                unsafe { w_he.write(hd, sum) };
                            }
                        })
                        .run(bk);
        }
    }

    fn stats(&mut self) -> Stats {
        use crate::dpp::timing::timed;
        timed("Reduce", || {
            stats_reduce(self.bk, self.ws, &self.amin, &self.y_elem)
        })
    }

    fn labels_changed(&mut self, delta: &mut crate::obs::LabelDelta)
        -> u64 {
        delta.update_u8(&self.labels)
    }

    fn take_labels(&mut self) -> Vec<u8> {
        std::mem::take(&mut self.labels)
    }
}

/// Optimized fused pipeline (§Perf; see [`PairMode::Fused`]).
///
/// Three static-segment passes per MAP iteration, all over
/// preallocated workspace (zero per-iteration allocation):
///
/// 1. **Map over hoods** (fused ReduceByKey + energy Map — the L1
///    kernel layout): per hood, sum the members' labels (`ones_h`),
///    then compute each member's fused energy-min and the hood's
///    energy sum. Both sweeps stay in cache.
/// 2. **ReduceByKey⟨Min⟩ over vertices** (static grouping): resolve
///    each vertex's label from its instances' packed minima.
/// 3. Per-label statistics via chunked Reduce (per EM iteration).
///
/// Bitwise-identical to the serial engine and to Paper mode (same
/// f32 op order within hoods/vertices).
struct FusedStep<'a> {
    bk: &'a dyn Device,
    ws: &'a Workspace,
    model: &'a MrfModel,
    y_elem: Vec<f32>,
    /// Grains in hood/vertex units scaled from the element grain.
    hood_grain: usize,
    vert_grain: usize,
    labels: Vec<u8>,
    // Workspace (allocated once).
    emin: Vec<f32>,
    amin: Vec<u8>,
    ones_h: Vec<f32>,
}

impl<'a> FusedStep<'a> {
    fn new(
        bk: &'a dyn Device,
        ws: &'a Workspace,
        model: &'a MrfModel,
        cfg: &MrfConfig,
    ) -> (FusedStep<'a>, Params) {
        let h = &model.hoods;
        let n = h.num_elements();
        let nh = h.num_hoods();
        let nv = model.num_vertices();
        let y_elem = model.y_elems();

        let elem_grain = bk.grain();
        let hood_grain =
            (elem_grain / (n / nh.max(1)).max(1)).clamp(1, usize::MAX);
        let vert_grain =
            (elem_grain / (n / nv.max(1)).max(1)).clamp(1, usize::MAX);

        let (prm, labels) =
            params::init_random(nv, cfg.beta as f32, cfg.seed);

        (
            FusedStep {
                bk,
                ws,
                model,
                y_elem,
                hood_grain,
                vert_grain,
                labels,
                // Once-per-run workspace tensors.
                emin: vec![0.0f32; n],    // alloc-ok: once per run
                amin: vec![0u8; n],       // alloc-ok: once per run
                ones_h: vec![0.0f32; nh], // alloc-ok: once per run
            },
            prm,
        )
    }
}

impl EmStep for FusedStep<'_> {
    fn map_iter(&mut self, prm: &Params, hood_energy: &mut [f64]) {
        use crate::dpp::core::SharedSlice;
        use crate::dpp::timing::timed;

        let bk = self.bk;
        let h = &self.model.hoods;
        let nh = h.num_hoods();
        let nv = self.model.num_vertices();

        // Pass 1: fused per-hood stats + energy map.
        let pp = energy::Prepared::from_params(prm);
        timed("Map", || {
            let we = SharedSlice::new(&mut self.emin);
            let wa = SharedSlice::new(&mut self.amin);
            let wo = SharedSlice::new(&mut self.ones_h);
            let wh = SharedSlice::new(hood_energy);
            let labels_ref = &self.labels;
            let y_ref = &self.y_elem;
            let prm_ref = &pp;
            bk.for_chunks_with(nh, self.hood_grain, |hs, he| {
                        for hd in hs..he {
                            let (s, e) = (
                                h.offsets[hd] as usize,
                                h.offsets[hd + 1] as usize,
                            );
                            let mut ones = 0.0f32;
                            for &v in &h.members[s..e] {
                                ones += labels_ref[v as usize] as f32;
                            }
                            let size = (e - s) as f32;
                            let mut sum = 0.0f64;
                            for el in s..e {
                                let lbl = labels_ref
                                    [h.members[el] as usize]
                                    as f32;
                                let (em, am) = energy::energy_min_p(
                                    y_ref[el], lbl, ones, size, prm_ref,
                                );
                                unsafe {
                                    we.write(el, em);
                                    wa.write(el, am);
                                }
                                sum += em as f64;
                            }
                            unsafe {
                                wo.write(hd, ones);
                                wh.write(hd, sum);
                            }
                        }
                    });
                });

        // Pass 2: per-vertex min-energy resolution (static
        // segmented ReduceByKey<Min>).
        timed("ReduceByKey", || {
            let wl = SharedSlice::new(&mut self.labels);
            let emin_ref = &self.emin;
            let amin_ref = &self.amin;
            bk.for_chunks_with(nv, self.vert_grain, |vs, ve| {
                        for v in vs..ve {
                            let (s, e) = (
                                h.vert_offsets[v] as usize,
                                h.vert_offsets[v + 1] as usize,
                            );
                            if s == e {
                                continue;
                            }
                            let mut best = u64::MAX;
                            for &el in &h.vert_elems[s..e] {
                                best = best.min(energy::pack_energy_label(
                                    emin_ref[el as usize],
                                    amin_ref[el as usize],
                                ));
                            }
                            unsafe {
                                wl.write(v, energy::unpack_label(best))
                            };
                        }
                    });
        });
    }

    fn stats(&mut self) -> Stats {
        use crate::dpp::timing::timed;
        timed("Reduce", || {
            stats_reduce(self.bk, self.ws, &self.amin, &self.y_elem)
        })
    }

    fn labels_changed(&mut self, delta: &mut crate::obs::LabelDelta)
        -> u64 {
        delta.update_u8(&self.labels)
    }

    fn take_labels(&mut self) -> Vec<u8> {
        std::mem::take(&mut self.labels)
    }
}

/// Per-label (count, sum, sumsq) via per-chunk accumulation merged in
/// chunk order (deterministic for a fixed backend); chunk bounds and
/// partials come from the workspace, so the per-EM-iteration M-step
/// allocates nothing once warm.
fn stats_reduce(
    bk: &dyn Device,
    ws: &Workspace,
    amin: &[u8],
    y: &[f32],
) -> Stats {
    let mut bounds = ws.take_spare::<(usize, usize)>(16);
    bk.chunk_bounds_into(amin.len(), &mut bounds);
    let mut partials = ws.take_filled::<Stats>(bounds.len(),
                                               Stats::default());
    {
        let win = crate::dpp::core::SharedSlice::new(&mut partials[..]);
        let bounds_ref = &bounds;
        bk.for_chunk_ids(bounds_ref.len(), |c| {
            let (s, e) = bounds_ref[c];
            let mut st = Stats::default();
            for i in s..e {
                st.add(amin[i], y[i]);
            }
            unsafe { win.write(c, st) };
        });
    }
    let mut total = Stats::default();
    for p in partials.iter() {
        total.merge(p);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OversegConfig;
    use crate::dpp::Backend;
    use crate::overseg::oversegment;
    use crate::pool::Pool;

    fn small_model(seed: u64) -> MrfModel {
        let v = crate::image::synth::porous_ground_truth(48, 48, 1, 0.42,
                                                         seed);
        let mut input = v.clone();
        crate::image::noise::additive_gaussian(&mut input, 60.0, seed);
        let seg = oversegment(
            &Backend::Serial,
            &input.slice(0),
            &OversegConfig { scale: 64.0, min_region: 4 },
        );
        crate::mrf::build_model_serial(&seg)
    }

    fn cfg_fixed() -> MrfConfig {
        MrfConfig { fixed_iters: true, em_iters: 4, map_iters: 3,
                    ..Default::default() }
    }

    #[test]
    fn dpp_serial_backend_matches_serial_engine_exactly() {
        let model = small_model(21);
        let cfg = cfg_fixed();
        let want = super::super::serial::SerialEngine.run(&model, &cfg);
        for mode in [PairMode::Paper, PairMode::Planned, PairMode::Fused] {
            let got = DppEngine::with_mode(Backend::Serial, mode)
                .run(&model, &cfg);
            assert_eq!(got.labels, want.labels, "mode {mode:?}");
            assert_eq!(got.params, want.params, "mode {mode:?}");
            for (a, b) in got.history.iter().zip(&want.history) {
                assert!((a - b).abs() < 1e-9 * b.abs().max(1.0),
                        "mode {mode:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn threaded_backend_agrees_statistically() {
        let model = small_model(22);
        let cfg = cfg_fixed();
        let want = super::super::serial::SerialEngine.run(&model, &cfg);
        let bk = Backend::threaded_with_grain(Pool::new(4), 256);
        for mode in [PairMode::Paper, PairMode::Planned, PairMode::Fused] {
            let got = DppEngine::with_mode(bk.clone(), mode)
                .run(&model, &cfg);
            let agree = got
                .labels
                .iter()
                .zip(&want.labels)
                .filter(|(a, b)| a == b)
                .count();
            let frac = agree as f64 / want.labels.len() as f64;
            assert!(frac > 0.999, "mode {mode:?}: agreement {frac}");
            let (a, b) = (got.energy, want.energy);
            assert!((a - b).abs() < 1e-6 * b.abs().max(1.0),
                    "energy {a} vs {b}");
        }
    }

    #[test]
    fn paper_and_fused_modes_identical() {
        let model = small_model(23);
        let cfg = cfg_fixed();
        let a = DppEngine::with_mode(Backend::Serial, PairMode::Paper)
            .run(&model, &cfg);
        let b = DppEngine::with_mode(Backend::Serial, PairMode::Fused)
            .run(&model, &cfg);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.params, b.params);
    }

    #[test]
    fn planned_mode_bitwise_matches_paper_on_both_backends() {
        // The plan-cached pipeline reduces every segment in the exact
        // order the per-iteration sort would have produced, and the
        // parameter reduce uses the same chunk bounds — so within one
        // backend, Planned must equal Paper bitwise.
        let model = small_model(26);
        let cfg = cfg_fixed();
        for bk in [
            Backend::Serial,
            Backend::threaded_with_grain(Pool::new(4), 256),
        ] {
            let a = DppEngine::with_mode(bk.clone(), PairMode::Paper)
                .run(&model, &cfg);
            let b = DppEngine::with_mode(bk.clone(), PairMode::Planned)
                .run(&model, &cfg);
            assert_eq!(a.labels, b.labels, "{bk:?}");
            assert_eq!(a.params, b.params, "{bk:?}");
            assert_eq!(a.history, b.history, "{bk:?}");
        }
    }

    #[test]
    fn planned_mode_sorts_once_per_run() {
        let model = small_model(27);
        let cfg = cfg_fixed(); // 4 EM x 3 MAP iterations
        // The pairing keys are sorted exactly once at plan build — not
        // once per MAP iteration (12 here) as in Paper mode. A scoped
        // recorder captures exactly this thread's rows (the serial
        // engine records on the calling thread), so no test_lock, no
        // retry loop, no cross-test interference.
        let rec = crate::telemetry::Recorder::new();
        {
            let _scope = rec.install();
            DppEngine::with_mode(Backend::Serial, PairMode::Planned)
                .run(&model, &cfg);
        }
        let snap = rec.snapshot();
        assert_eq!(
            snap.time_rows["SortByKey"].calls, 1,
            "sort amortized to one per run"
        );
        assert!(snap.time_rows.contains_key("ReduceByKey"));
        assert!(snap.time_rows.contains_key("Gather"));
        assert!(snap.time_rows.contains_key("Map"));
    }

    #[test]
    fn convergence_mode_runs() {
        let model = small_model(24);
        let cfg = MrfConfig::default();
        let res = DppEngine::new(Backend::Serial).run(&model, &cfg);
        assert!(res.em_iters <= cfg.em_iters);
        assert!(res.labels.iter().all(|&l| l <= 1));
    }

    #[test]
    fn per_dpp_timing_records_sort_in_paper_mode() {
        let model = small_model(25);
        let cfg = cfg_fixed();
        // Scoped recorder: no global registry, no test_lock.
        let rec = crate::telemetry::Recorder::new();
        {
            let _scope = rec.install();
            DppEngine::with_mode(Backend::Serial, PairMode::Paper)
                .run(&model, &cfg);
        }
        let snap = rec.snapshot();
        assert!(snap.time_rows.contains_key("SortByKey"));
        assert!(snap.time_rows.contains_key("ReduceByKey"));
        assert!(snap.time_rows.contains_key("Map"));
        assert!(snap.time_rows.contains_key("Gather"));
        assert!(snap.time_rows.contains_key("Scatter"));
        // The Workspace counters migrated to first-class telemetry
        // counters land in the same snapshot, outside the time rows.
        assert!(snap.counters.contains_key("Workspace::miss"));
        assert!(snap.gauges.contains_key("Workspace::high_water_bytes"));
        assert_eq!(
            snap.time_rows.keys().filter(|k| k.starts_with("Workspace::"))
                .count(),
            0,
            "counters no longer pollute time rows"
        );
    }
}
