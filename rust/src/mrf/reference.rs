//! Reference coarse-parallel engine — the OpenMP PMRF analog (Alg. 1,
//! §3.1/§4.1.4).
//!
//! Structure mirrors the paper's reference implementation:
//!
//! * **outer parallelism only**: one task per neighborhood on the
//!   shared pool (OpenMP `parallel for schedule(dynamic)` analog);
//! * **serial inner optimization**: each task computes its hood's
//!   label-1 count, member energies, and argmins in a plain loop;
//! * the **critical section**: like the paper's code (§4.3.3), each
//!   task serializes on one mutex to write its results row into the
//!   shared output buffers — the documented scalability limiter, kept
//!   deliberately faithful (toggle with [`ReferenceEngine::no_critical`]
//!   for the ablation bench);
//! * vertex resolution and parameter updates run serially between MAP
//!   iterations, exactly as in the serial engine.
//!
//! Numerically identical to [`super::serial::SerialEngine`] — the
//! parallel structure changes, the math and its ordering do not.

use std::sync::Arc;
use std::sync::Mutex;

use crate::config::MrfConfig;
use crate::pool::Pool;

use super::energy;
use super::params::{self, Stats};
use super::{ConvergenceWindow, Engine, EmResult, HoodWindows, MrfModel};

pub struct ReferenceEngine {
    pool: Arc<Pool>,
    /// Disable the output critical section (ablation; default keeps it,
    /// as in the paper).
    pub no_critical: bool,
}

impl ReferenceEngine {
    pub fn new(pool: Arc<Pool>) -> Self {
        ReferenceEngine { pool, no_critical: false }
    }

    pub fn without_critical_section(pool: Arc<Pool>) -> Self {
        ReferenceEngine { pool, no_critical: true }
    }
}

impl Engine for ReferenceEngine {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn run(&self, model: &MrfModel, cfg: &MrfConfig) -> EmResult {
        let h = &model.hoods;
        let n = h.num_elements();
        let nh = h.num_hoods();
        let nv = model.num_vertices();
        let y_elem = model.y_elems();

        let (mut prm, mut labels) =
            params::init_random(nv, cfg.beta as f32, cfg.seed);

        let size_h: Vec<f32> =
            (0..nh).map(|i| h.hood_size(i) as f32).collect();

        let mut emin = vec![0.0f32; n];
        let mut amin = vec![0u8; n];
        let mut hood_energy = vec![0.0f64; nh];

        let mut em_window = ConvergenceWindow::new(cfg.window, cfg.threshold);
        let mut total_map = 0usize;
        let mut em_iters = 0usize;
        let critical = Mutex::new(());
        // Flight-recorder state (armed runs only): seed once so every
        // in-loop sample reports a true delta.
        let mut delta = crate::obs::LabelDelta::new();
        if crate::obs::armed() {
            delta.update_u8(&labels);
        }

        for _em in 0..cfg.em_iters {
            em_iters += 1;
            let mut hw = HoodWindows::new(nh, cfg.window, cfg.threshold);
            for _map in 0..cfg.map_iters {
                total_map += 1;
                let pp = energy::Prepared::from_params(&prm);

                // ---- outer-parallel over neighborhoods ----
                {
                    let labels_ref = &labels;
                    let emin_win =
                        crate::dpp::core::SharedSlice::new(&mut emin);
                    let amin_win =
                        crate::dpp::core::SharedSlice::new(&mut amin);
                    let he_win =
                        crate::dpp::core::SharedSlice::new(&mut hood_energy);
                    let size_h_ref = &size_h;
                    let y_ref = &y_elem;
                    let crit = &critical;
                    self.pool.parallel_tasks(nh, |hood| {
                        let (s, e) = (
                            h.offsets[hood] as usize,
                            h.offsets[hood + 1] as usize,
                        );
                        // Serial inner computation on a local row
                        // (the OpenMP code's per-thread workspace).
                        let mut ones = 0.0f32;
                        for &v in &h.members[s..e] {
                            ones += labels_ref[v as usize] as f32;
                        }
                        let mut row_e = Vec::with_capacity(e - s);
                        let mut row_a = Vec::with_capacity(e - s);
                        let mut sum = 0.0f64;
                        for (i, &v) in h.members[s..e].iter().enumerate() {
                            let lbl = labels_ref[v as usize] as f32;
                            let (em, am) = energy::energy_min_p(
                                y_ref[s + i],
                                lbl,
                                ones,
                                size_h_ref[hood],
                                &pp,
                            );
                            row_e.push(em);
                            row_a.push(am);
                            sum += em as f64;
                        }
                        // The paper's critical section: the write-back
                        // of the row into the shared ragged output is
                        // serialized.
                        let guard = if self.no_critical {
                            None
                        } else {
                            Some(crit.lock().unwrap())
                        };
                        for i in 0..row_e.len() {
                            unsafe {
                                emin_win.write(s + i, row_e[i]);
                                amin_win.write(s + i, row_a[i]);
                            }
                        }
                        unsafe { he_win.write(hood, sum) };
                        drop(guard);
                    });
                }

                // ---- serial between-iteration steps (as in Alg. 1) ----
                super::serial::resolve_vertices_serial(
                    model, &emin, &amin, &mut labels,
                );
                // Flight-recorder hook (DESIGN.md §13): one relaxed
                // load when off.
                if crate::obs::live() {
                    if crate::obs::armed() {
                        let changed = delta.update_u8(&labels);
                        let energy: f64 = hood_energy.iter().sum();
                        crate::obs::map_sample(
                            em_iters - 1, total_map - 1, energy, changed,
                        );
                    } else {
                        crate::obs::tick();
                    }
                }
                let done = hw.push_all(&hood_energy);
                if done && !cfg.fixed_iters {
                    break;
                }
            }

            let mut stats = Stats::default();
            for e in 0..n {
                stats.add(amin[e], y_elem[e]);
            }
            prm = params::update(&stats, cfg.beta as f32);

            let total: f64 = hood_energy.iter().sum();
            em_window.push(total);
            if em_window.converged() && !cfg.fixed_iters {
                break;
            }
        }

        EmResult {
            labels,
            em_iters,
            map_iters: total_map,
            energy: *em_window.history().last().unwrap_or(&0.0),
            history: em_window.history().to_vec(),
            params: prm,
            lower_bound: None,
            pmp: None,
            bp: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OversegConfig;
    use crate::dpp::Backend;
    use crate::image::synth;
    use crate::overseg::oversegment;

    fn small_model(seed: u64) -> MrfModel {
        let v = synth::porous_ground_truth(48, 48, 1, 0.42, seed);
        let mut input = v.clone();
        crate::image::noise::additive_gaussian(&mut input, 60.0, seed);
        let seg = oversegment(
            &Backend::Serial,
            &input.slice(0),
            &OversegConfig { scale: 64.0, min_region: 4 },
        );
        crate::mrf::build_model_serial(&seg)
    }

    #[test]
    fn matches_serial_engine_exactly() {
        let model = small_model(11);
        let cfg = MrfConfig { fixed_iters: true, em_iters: 4, map_iters: 3,
                              ..Default::default() };
        let want = super::super::serial::SerialEngine.run(&model, &cfg);
        for threads in [1, 4] {
            let eng = ReferenceEngine::new(Pool::new(threads));
            let got = eng.run(&model, &cfg);
            assert_eq!(got.labels, want.labels, "threads={threads}");
            assert_eq!(got.params, want.params);
            assert_eq!(got.history, want.history);
        }
    }

    #[test]
    fn no_critical_variant_identical_results() {
        let model = small_model(12);
        let cfg = MrfConfig { fixed_iters: true, em_iters: 3, map_iters: 3,
                              ..Default::default() };
        let with = ReferenceEngine::new(Pool::new(4)).run(&model, &cfg);
        let without = ReferenceEngine::without_critical_section(Pool::new(4))
            .run(&model, &cfg);
        assert_eq!(with.labels, without.labels);
        assert_eq!(with.history, without.history);
    }

    #[test]
    fn convergence_mode_terminates_early() {
        let model = small_model(13);
        let cfg = MrfConfig::default();
        let res = ReferenceEngine::new(Pool::new(2)).run(&model, &cfg);
        assert!(res.em_iters <= cfg.em_iters);
        assert!(res.labels.iter().all(|&l| l <= 1));
    }
}
