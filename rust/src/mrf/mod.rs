//! MRF model + EM/MAP optimization engines.
//!
//! The shared semantics every engine implements (DESIGN.md §5):
//!
//! * One **MAP iteration** (Jacobi update): from the current per-vertex
//!   labels, compute per-hood label-1 counts; evaluate both label
//!   energies per hood-member instance ([`energy`]); per instance take
//!   the argmin; resolve each **vertex** to the minimum-energy label
//!   across its instances (ties -> label 0, deterministic); per-hood
//!   energy = sum of instance minima.
//! * A hood/EM quantity is **converged** when it changed by less than
//!   `threshold * max(|old|, 1)` relative to `window` iterations ago.
//! * One **EM iteration** = MAP iterations until all hoods converge (or
//!   `map_iters`), then re-estimate (mu, sigma) from the instance-level
//!   argmin labels ([`params::update`]).
//! * The EM loop stops when the total energy converges (or `em_iters`).
//!   With `fixed_iters` every loop runs its full count — used by tests
//!   to compare engines exactly.
//!
//! Engines: [`serial::SerialEngine`] (baseline),
//! [`reference::ReferenceEngine`] (coarse-parallel OpenMP analog),
//! [`dpp::DppEngine`] (the paper's contribution),
//! [`xla::XlaEngine`] (AOT accelerator path),
//! [`crate::bp::BpEngine`] (loopy belief propagation, DESIGN.md §6),
//! [`crate::dual::DualEngine`] (dual block-coordinate ascent with
//! certified lower bounds, DESIGN.md §12), and
//! [`crate::pmp::PmpEngine`] (particle max-product over the
//! [`continuous`] label model, DESIGN.md §14).
//! Construct by kind through [`make_engine`].

pub mod continuous;
pub mod dpp;
pub mod energy;
pub mod hoods;
pub mod params;
pub mod reference;
pub mod serial;
pub mod xla;

pub use energy::Params;
pub use hoods::Hoods;

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::{EngineKind, MrfConfig};
use crate::dpp::{Device, IntoDevice};
use crate::graph::Csr;
use crate::overseg::Overseg;
use crate::pool::Pool;
use crate::runtime::EmRuntime;

/// The optimization problem: graph, observations, neighborhoods.
#[derive(Debug, Clone)]
pub struct MrfModel {
    pub graph: Csr,
    /// Observation per vertex: mean region intensity (0..255).
    pub y: Vec<f32>,
    pub hoods: Hoods,
}

impl MrfModel {
    pub fn num_vertices(&self) -> usize {
        self.graph.num_vertices()
    }

    /// Per-element observation (gather of `y` through hood members).
    pub fn y_elems(&self) -> Vec<f32> {
        self.hoods.members.iter().map(|&v| self.y[v as usize]).collect()
    }
}

/// Full model construction from an oversegmentation: RAG -> maximal
/// cliques -> 1-neighborhoods, all through the DPP pipeline.
pub fn build_model(bk: &dyn Device, seg: &Overseg) -> MrfModel {
    let graph = crate::graph::build_rag_dpp(bk, seg);
    let cliques = crate::mce::enumerate_dpp(bk, &graph);
    let hoods =
        hoods::build_dpp(bk, &graph, &cliques, graph.num_vertices());
    MrfModel { y: seg.mean.clone(), graph, hoods }
}

/// Serial model construction (test oracle).
pub fn build_model_serial(seg: &Overseg) -> MrfModel {
    let graph = crate::graph::build_rag_serial(seg);
    let cliques = crate::mce::enumerate_serial(&graph);
    let hoods =
        hoods::build_serial(&graph, &cliques, graph.num_vertices());
    MrfModel { y: seg.mean.clone(), graph, hoods }
}

/// Output of one EM optimization run.
#[derive(Debug, Clone, PartialEq)]
pub struct EmResult {
    /// Final label per vertex (0/1).
    pub labels: Vec<u8>,
    /// EM iterations actually executed.
    pub em_iters: usize,
    /// Total MAP iterations across all EM iterations.
    pub map_iters: usize,
    /// Final total energy.
    pub energy: f64,
    /// Total energy after each EM iteration.
    pub history: Vec<f64>,
    /// Final estimated parameters.
    pub params: Params,
    /// Certified lower bound on the final labeling energy (same
    /// parameters as `energy`), from engines that can prove one via
    /// weak duality ([`crate::dual`]); `None` for engines that
    /// cannot certify.
    pub lower_bound: Option<f64>,
    /// Particle statistics (counts, proposal acceptance, continuous
    /// max-marginal energy) from the particle max-product engine
    /// ([`crate::pmp`]); `None` for the discrete engines.
    pub pmp: Option<crate::pmp::PmpStats>,
    /// Frontier-policy statistics (schedule + committed fraction)
    /// from the BP engine ([`crate::bp`], DESIGN.md §15); `None` for
    /// every other engine family.
    pub bp: Option<crate::bp::BpStats>,
}

/// An EM/MAP optimization engine.
pub trait Engine {
    fn name(&self) -> &'static str;
    fn run(&self, model: &MrfModel, cfg: &MrfConfig) -> EmResult;
}

/// Everything [`make_engine`] may need; callers fill in what they have
/// (`runtime` is only required for [`EngineKind::Xla`], and there only
/// when the device itself carries no accelerator runtime).
#[derive(Clone)]
pub struct EngineResources {
    pub pool: Arc<Pool>,
    /// The device every engine's primitives execute on.
    pub device: Arc<dyn Device>,
    pub runtime: Option<Arc<EmRuntime>>,
    pub bp: crate::bp::BpConfig,
    pub dual: crate::dual::DualConfig,
    pub pmp: crate::pmp::PmpConfig,
}

impl EngineResources {
    /// Resources for the pure-CPU engines (serial/reference/dpp/bp).
    /// Accepts a concrete device, an `Arc<dyn Device>`, or the
    /// deprecated `Backend` spelling.
    pub fn new(pool: Arc<Pool>, device: impl IntoDevice)
        -> EngineResources {
        EngineResources {
            pool,
            device: device.into_device(),
            runtime: None,
            bp: crate::bp::BpConfig::default(),
            dual: crate::dual::DualConfig::default(),
            pmp: crate::pmp::PmpConfig::default(),
        }
    }
}

/// The single construction site for every [`EngineKind`] — the
/// coordinator and launcher both dispatch through here, so adding an
/// engine means one new arm, not one per caller.
pub fn make_engine(kind: EngineKind, res: &EngineResources)
    -> Result<Box<dyn Engine>> {
    Ok(match kind {
        EngineKind::Serial => Box::new(serial::SerialEngine),
        EngineKind::Reference => {
            Box::new(reference::ReferenceEngine::new(Arc::clone(&res.pool)))
        }
        EngineKind::Dpp => {
            Box::new(dpp::DppEngine::new(Arc::clone(&res.device)))
        }
        EngineKind::Xla => Box::new(xla::XlaEngine::new(
            res.runtime
                .clone()
                .or_else(|| res.device.accelerator_runtime())
                .context("xla engine needs loaded artifacts (pass a \
                          runtime or an accel device with artifacts)")?,
        )),
        EngineKind::Bp => Box::new(crate::bp::BpEngine::new(
            Arc::clone(&res.device),
            res.bp,
        )),
        EngineKind::Dual => Box::new(crate::dual::DualEngine::new(
            Arc::clone(&res.device),
            res.dual,
        )),
        EngineKind::Pmp => Box::new(crate::pmp::PmpEngine::new(
            Arc::clone(&res.device),
            res.pmp,
        )),
    })
}

/// Energy of a concrete labeling under the shared hood-energy
/// definition (DESIGN.md §5): per hood-member instance, the energy of
/// the vertex's assigned label, summed per hood. At a MAP fixpoint this
/// equals the engines' reported energy; the BP engine and the
/// cross-engine quality tests score labelings with it.
pub fn config_energy(model: &MrfModel, labels: &[u8], prm: &Params)
    -> (Vec<f64>, f64) {
    let h = &model.hoods;
    let pp = energy::Prepared::from_params(prm);
    let hood_energy: Vec<f64> = (0..h.num_hoods())
        .map(|hd| {
            hood_label_energy(h.hood_members(hd), &model.y, labels, &pp)
        })
        .collect();
    let total = hood_energy.iter().sum();
    (hood_energy, total)
}

/// One hood's labeling energy — the single accumulation both
/// [`config_energy`] and the BP engine's fused parallel scorer run, so
/// their bitwise equality is structural: label-1 count over the
/// members in order, then each member's energy at its assigned label.
pub(crate) fn hood_label_energy(
    members: &[u32],
    y: &[f32],
    labels: &[u8],
    pp: &energy::Prepared,
) -> f64 {
    let mut ones = 0.0f32;
    for &v in members {
        ones += labels[v as usize] as f32;
    }
    let size = members.len() as f32;
    let mut sum = 0.0f64;
    for &v in members {
        let lbl = labels[v as usize];
        let (e0, e1) = energy::energy_pair_p(
            y[v as usize],
            lbl as f32,
            ones,
            size,
            pp,
        );
        sum += if lbl == 1 { e1 as f64 } else { e0 as f64 };
    }
    sum
}

/// Windowed relative-change convergence test (paper: L=3, 1e-4).
#[derive(Debug, Clone)]
pub struct ConvergenceWindow {
    hist: Vec<f64>,
    window: usize,
    threshold: f64,
}

impl ConvergenceWindow {
    pub fn new(window: usize, threshold: f64) -> Self {
        ConvergenceWindow { hist: Vec::new(), window: window.max(1),
                            threshold }
    }

    pub fn push(&mut self, v: f64) {
        self.hist.push(v);
    }

    /// Converged iff the latest value moved < threshold (relative)
    /// versus `window` iterations ago.
    pub fn converged(&self) -> bool {
        let n = self.hist.len();
        if n <= self.window {
            return false;
        }
        let old = self.hist[n - 1 - self.window];
        let new = self.hist[n - 1];
        (new - old).abs() < self.threshold * old.abs().max(1.0)
    }

    pub fn history(&self) -> &[f64] {
        &self.hist
    }
}

/// Flat ring-buffer of per-hood energy histories for the MAP
/// convergence check — all engines share this exact logic.
#[derive(Debug, Clone)]
pub struct HoodWindows {
    ring: Vec<f64>,
    num_hoods: usize,
    window: usize,
    threshold: f64,
    iter: usize,
}

impl HoodWindows {
    pub fn new(num_hoods: usize, window: usize, threshold: f64) -> Self {
        let window = window.max(1);
        HoodWindows {
            ring: vec![0.0; num_hoods * (window + 1)],
            num_hoods,
            window,
            threshold,
            iter: 0,
        }
    }

    /// Forget all recorded history — equivalent to a freshly
    /// constructed instance with the same shape. Lets the EM driver
    /// hoist the one ring allocation out of the EM loop and reuse it
    /// every iteration (the zero-allocation steady state, DESIGN.md
    /// §10).
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::mrf::HoodWindows;
    /// let mut hw = HoodWindows::new(1, 1, 1e-3);
    /// hw.push_all(&[5.0]);
    /// assert!(hw.push_all(&[5.0])); // converged
    /// hw.reset();
    /// assert!(!hw.push_all(&[5.0])); // history gone: not converged
    /// ```
    pub fn reset(&mut self) {
        self.ring.fill(0.0);
        self.iter = 0;
    }

    /// Record this iteration's hood energies; returns true when EVERY
    /// hood satisfies the windowed convergence criterion.
    pub fn push_all(&mut self, energies: &[f64]) -> bool {
        assert_eq!(energies.len(), self.num_hoods);
        let slot = self.iter % (self.window + 1);
        self.ring[slot * self.num_hoods..(slot + 1) * self.num_hoods]
            .copy_from_slice(energies);
        self.iter += 1;
        if self.iter <= self.window {
            return false;
        }
        // Oldest slot in the ring = iter - window.
        let old_slot = (self.iter - 1 - self.window) % (self.window + 1);
        let old = &self.ring
            [old_slot * self.num_hoods..(old_slot + 1) * self.num_hoods];
        energies.iter().zip(old).all(|(&new, &old)| {
            (new - old).abs() < self.threshold * old.abs().max(1.0)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::Backend;

    #[test]
    fn window_needs_history() {
        let mut w = ConvergenceWindow::new(3, 1e-4);
        for v in [10.0, 10.0, 10.0] {
            w.push(v);
            assert!(!w.converged(), "not enough history");
        }
        w.push(10.0);
        assert!(w.converged());
    }

    #[test]
    fn window_detects_change() {
        let mut w = ConvergenceWindow::new(2, 1e-4);
        for v in [100.0, 90.0, 80.0, 70.0] {
            w.push(v);
        }
        assert!(!w.converged());
        w.push(80.0 - 80.0 * 1e-5); // within 1e-4 of 2-ago
        assert!(w.converged());
    }

    #[test]
    fn hood_windows_all_must_converge() {
        let mut hw = HoodWindows::new(2, 1, 1e-3);
        assert!(!hw.push_all(&[5.0, 7.0]));
        // hood 0 stable, hood 1 moving
        assert!(!hw.push_all(&[5.0, 6.0]));
        // both stable vs previous iteration
        assert!(hw.push_all(&[5.0, 6.0]));
    }

    #[test]
    fn hood_windows_relative_scale() {
        let mut hw = HoodWindows::new(1, 1, 1e-4);
        hw.push_all(&[1.0e6]);
        // 1e-4 relative on 1e6 allows drift of 100
        assert!(hw.push_all(&[1.0e6 + 50.0]));
    }

    #[test]
    fn config_energy_matches_serial_engine_at_convergence() {
        let model = crate::bp::test_model(61);
        let cfg = MrfConfig::default();
        let res = serial::SerialEngine.run(&model, &cfg);
        let (hood_e, total) =
            config_energy(&model, &res.labels, &res.params);
        assert_eq!(hood_e.len(), model.hoods.num_hoods());
        // At convergence the labeling energy and the engine's reported
        // per-instance-minimum sum coincide up to residual label churn.
        let rel = (total - res.energy).abs() / res.energy.abs().max(1.0);
        assert!(rel < 0.02, "config {total} vs engine {} ", res.energy);
    }

    #[test]
    fn factory_builds_every_cpu_engine() {
        let pool = crate::pool::Pool::new(2);
        let res = EngineResources::new(Arc::clone(&pool),
                                       Backend::threaded(pool));
        for (kind, name) in [
            (EngineKind::Serial, "serial"),
            (EngineKind::Reference, "reference"),
            (EngineKind::Dpp, "dpp"),
            (EngineKind::Bp, "bp"),
            (EngineKind::Dual, "dual"),
            (EngineKind::Pmp, "pmp"),
        ] {
            let e = make_engine(kind, &res).unwrap();
            assert_eq!(e.name(), name);
        }
        // Xla without a loaded runtime is a clean error, not a panic.
        assert!(make_engine(EngineKind::Xla, &res).is_err());
    }
}
