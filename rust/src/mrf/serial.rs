//! Serial baseline engine — the "Serial CPU" row of Table 1 and the
//! numeric oracle for every other engine (straight loops, hood-major
//! element order everywhere).

use crate::config::MrfConfig;

use super::energy::{self, Params};
use super::params::{self, Stats};
use super::{ConvergenceWindow, Engine, EmResult, HoodWindows, MrfModel};

#[derive(Debug, Default, Clone, Copy)]
pub struct SerialEngine;

impl Engine for SerialEngine {
    fn name(&self) -> &'static str {
        "serial"
    }

    fn run(&self, model: &MrfModel, cfg: &MrfConfig) -> EmResult {
        let h = &model.hoods;
        let n = h.num_elements();
        let nh = h.num_hoods();
        let nv = model.num_vertices();
        let y_elem = model.y_elems();

        let (mut prm, mut labels) =
            params::init_random(nv, cfg.beta as f32, cfg.seed);

        // Static per-element hood sizes.
        let size_e: Vec<f32> = (0..n)
            .map(|e| h.hood_size(h.hood_id[e] as usize) as f32)
            .collect();

        let mut emin = vec![0.0f32; n];
        let mut amin = vec![0u8; n];
        let mut ones_h = vec![0.0f32; nh];
        let mut hood_energy = vec![0.0f64; nh];

        let mut em_window = ConvergenceWindow::new(cfg.window, cfg.threshold);
        let mut total_map = 0usize;
        let mut em_iters = 0usize;
        // Flight-recorder state (armed runs only): seed the
        // labels-changed counter before the loop so every in-loop
        // sample reports a true delta.
        let mut delta = crate::obs::LabelDelta::new();
        if crate::obs::armed() {
            delta.update_u8(&labels);
        }

        for _em in 0..cfg.em_iters {
            em_iters += 1;
            let mut hw = HoodWindows::new(nh, cfg.window, cfg.threshold);
            for _map in 0..cfg.map_iters {
                total_map += 1;
                map_iteration(
                    model, &prm, &labels, &y_elem, &size_e, &mut ones_h,
                    &mut emin, &mut amin, &mut hood_energy,
                );
                resolve_vertices(model, &emin, &amin, &mut labels);
                // Flight-recorder hook (DESIGN.md §13): one relaxed
                // load when off.
                if crate::obs::live() {
                    if crate::obs::armed() {
                        let changed = delta.update_u8(&labels);
                        let energy: f64 = hood_energy.iter().sum();
                        crate::obs::map_sample(
                            em_iters - 1, total_map - 1, energy, changed,
                        );
                    } else {
                        crate::obs::tick();
                    }
                }
                let done = hw.push_all(&hood_energy);
                if done && !cfg.fixed_iters {
                    break;
                }
            }
            // Parameter update from the final MAP iteration's labels.
            let mut stats = Stats::default();
            for e in 0..n {
                stats.add(amin[e], y_elem[e]);
            }
            prm = params::update(&stats, cfg.beta as f32);

            let total: f64 = hood_energy.iter().sum();
            em_window.push(total);
            if em_window.converged() && !cfg.fixed_iters {
                break;
            }
        }

        EmResult {
            labels,
            em_iters,
            map_iters: total_map,
            energy: *em_window.history().last().unwrap_or(&0.0),
            history: em_window.history().to_vec(),
            params: prm,
            lower_bound: None,
            pmp: None,
            bp: None,
        }
    }
}

/// One Jacobi MAP iteration, fully serial. Factored out so the
/// reference engine can reuse the identical math per hood.
#[allow(clippy::too_many_arguments)]
fn map_iteration(
    model: &MrfModel,
    prm: &Params,
    labels: &[u8],
    y_elem: &[f32],
    size_e: &[f32],
    ones_h: &mut [f32],
    emin: &mut [f32],
    amin: &mut [u8],
    hood_energy: &mut [f64],
) {
    let h = &model.hoods;
    let pp = energy::Prepared::from_params(prm);
    // Per-hood label-1 counts from the current labels.
    ones_h.fill(0.0);
    for (e, &v) in h.members.iter().enumerate() {
        ones_h[h.hood_id[e] as usize] += labels[v as usize] as f32;
    }
    // Per-element fused energy + argmin; accumulate hood sums.
    hood_energy.fill(0.0);
    for e in 0..h.num_elements() {
        let hid = h.hood_id[e] as usize;
        let lbl = labels[h.members[e] as usize] as f32;
        let (em, am) =
            energy::energy_min_p(y_elem[e], lbl, ones_h[hid], size_e[e], &pp);
        emin[e] = em;
        amin[e] = am;
        hood_energy[hid] += em as f64;
    }
}

/// Per-vertex resolution: minimum-energy label across the vertex's
/// hood-member instances (ties -> label 0 via the packed encoding).
pub(crate) fn resolve_vertices(
    model: &MrfModel,
    emin: &[f32],
    amin: &[u8],
    labels: &mut [u8],
) {
    let h = &model.hoods;
    for v in 0..labels.len() {
        let (s, e) =
            (h.vert_offsets[v] as usize, h.vert_offsets[v + 1] as usize);
        if s == e {
            continue; // vertex in no hood: keep current label
        }
        let mut best = u64::MAX;
        for &el in &h.vert_elems[s..e] {
            let packed = energy::pack_energy_label(
                emin[el as usize],
                amin[el as usize],
            );
            best = best.min(packed);
        }
        labels[v] = energy::unpack_label(best);
    }
}

// Expose the vertex resolution to sibling engines (same math,
// different parallel structure).
pub(crate) use resolve_vertices as resolve_vertices_serial;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OversegConfig;
    use crate::dpp::Backend;
    use crate::image::synth;
    use crate::overseg::oversegment;

    fn small_model(seed: u64) -> MrfModel {
        let v = synth::porous_ground_truth(48, 48, 1, 0.42, seed);
        let mut input = v.clone();
        crate::image::noise::additive_gaussian(&mut input, 60.0, seed);
        let seg = oversegment(
            &Backend::Serial,
            &input.slice(0),
            &OversegConfig { scale: 64.0, min_region: 4 },
        );
        crate::mrf::build_model_serial(&seg)
    }

    #[test]
    fn energy_decreases_and_converges() {
        let model = small_model(3);
        let cfg = MrfConfig::default();
        let res = SerialEngine.run(&model, &cfg);
        assert!(res.em_iters <= cfg.em_iters);
        assert!(res.history.len() == res.em_iters);
        // Energy after the final EM iteration should not exceed the
        // first iteration's energy (EM is monotone up to re-estimation
        // noise; allow tiny slack).
        let first = res.history[0];
        let last = res.energy;
        assert!(last <= first + first.abs() * 0.05,
                "first={first} last={last}");
    }

    #[test]
    fn labels_binary_and_deterministic() {
        let model = small_model(4);
        let cfg = MrfConfig::default();
        let a = SerialEngine.run(&model, &cfg);
        let b = SerialEngine.run(&model, &cfg);
        assert_eq!(a, b);
        assert!(a.labels.iter().all(|&l| l <= 1));
        assert_eq!(a.labels.len(), model.num_vertices());
    }

    #[test]
    fn segmentation_separates_bimodal_regions() {
        // Build an easy bimodal model and check the labeling splits it
        // by intensity.
        let model = small_model(5);
        let cfg = MrfConfig { em_iters: 20, ..Default::default() };
        let res = SerialEngine.run(&model, &cfg);
        // vertices with y close to each estimated mean should mostly
        // carry the corresponding label
        let mut agree = 0usize;
        let mut total = 0usize;
        for (v, &l) in res.labels.iter().enumerate() {
            let y = model.y[v];
            let d0 = (y - res.params.mu[0]).abs();
            let d1 = (y - res.params.mu[1]).abs();
            // only count confident vertices
            if (d0 - d1).abs() > 20.0 {
                total += 1;
                let want = u8::from(d1 < d0);
                agree += usize::from(l == want);
            }
        }
        assert!(total > 0);
        assert!(agree as f64 / total as f64 > 0.9,
                "agree {agree}/{total}");
    }

    #[test]
    fn fixed_iters_runs_exact_counts() {
        let model = small_model(6);
        let cfg = MrfConfig {
            em_iters: 3,
            map_iters: 4,
            fixed_iters: true,
            ..Default::default()
        };
        let res = SerialEngine.run(&model, &cfg);
        assert_eq!(res.em_iters, 3);
        assert_eq!(res.map_iters, 12);
    }
}
