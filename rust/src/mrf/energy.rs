//! The MRF energy function — single source of truth for all engines.
//!
//! MUST stay in lockstep with the L1 Pallas kernel
//! (`python/compile/kernels/energy.py`) and its jnp oracle
//! (`kernels/ref.py`): same formula, same f32 operations, same strict
//! `e1 < e0` argmin tie-break (ties pick label 0).
//!
//! ```text
//! E(v, l) = (y_v - mu_l)^2 / (2 sigma_l^2) + ln(sigma_l)
//!           + beta * disagree(v, l)
//! disagree(v, 0) = ones_h - label_v
//! disagree(v, 1) = (size_h - ones_h) - (1 - label_v)
//! ```

/// Label-model parameters for the binary segmentation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Params {
    pub mu: [f32; 2],
    pub sigma: [f32; 2],
    pub beta: f32,
}

/// Per-MAP-iteration invariants hoisted out of the element loop
/// (§Perf): reciprocal of 2σ² and ln σ are computed once per label per
/// iteration instead of twice per element. Every engine evaluates
/// energies through this, so results stay engine-identical.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Prepared {
    pub mu: [f32; 2],
    /// 1 / (2 sigma_l^2)
    pub inv2s: [f32; 2],
    /// ln(sigma_l)
    pub lns: [f32; 2],
    pub beta: f32,
}

impl Prepared {
    #[inline]
    pub fn from_params(p: &Params) -> Prepared {
        Prepared {
            mu: p.mu,
            inv2s: [
                1.0 / (2.0 * p.sigma[0] * p.sigma[0]),
                1.0 / (2.0 * p.sigma[1] * p.sigma[1]),
            ],
            lns: [p.sigma[0].ln(), p.sigma[1].ln()],
            beta: p.beta,
        }
    }
}

/// Both label energies for one hood-member instance.
#[inline(always)]
pub fn energy_pair_p(
    y: f32,
    label: f32,
    ones_h: f32,
    size_h: f32,
    p: &Prepared,
) -> (f32, f32) {
    let d0 = y - p.mu[0];
    let d1 = y - p.mu[1];
    let e0 = d0 * d0 * p.inv2s[0] + p.lns[0];
    let e1 = d1 * d1 * p.inv2s[1] + p.lns[1];
    let dis0 = ones_h - label;
    let dis1 = (size_h - ones_h) - (1.0 - label);
    (e0 + p.beta * dis0, e1 + p.beta * dis1)
}

/// Both label energies (convenience over raw [`Params`]).
#[inline(always)]
pub fn energy_pair(
    y: f32,
    label: f32,
    ones_h: f32,
    size_h: f32,
    p: &Params,
) -> (f32, f32) {
    energy_pair_p(y, label, ones_h, size_h, &Prepared::from_params(p))
}

/// Fused energy + argmin over prepared params: (min_energy, label).
#[inline(always)]
pub fn energy_min_p(
    y: f32,
    label: f32,
    ones_h: f32,
    size_h: f32,
    p: &Prepared,
) -> (f32, u8) {
    let (e0, e1) = energy_pair_p(y, label, ones_h, size_h, p);
    if e1 < e0 { (e1, 1) } else { (e0, 0) }
}

/// Fused energy + argmin, the kernel's contract: (min_energy, label).
#[inline(always)]
pub fn energy_min(
    y: f32,
    label: f32,
    ones_h: f32,
    size_h: f32,
    p: &Params,
) -> (f32, u8) {
    let (e0, e1) = energy_pair(y, label, ones_h, size_h, p);
    if e1 < e0 { (e1, 1) } else { (e0, 0) }
}

/// Order-preserving map from f32 to u32: `a < b` (as floats, no NaNs)
/// iff `sortable(a) < sortable(b)`. The standard radix-sort float trick.
#[inline(always)]
pub fn sortable_f32(x: f32) -> u32 {
    let bits = x.to_bits();
    if bits & 0x8000_0000 != 0 { !bits } else { bits | 0x8000_0000 }
}

/// Pack (energy, label) so u64-min selects minimum energy, ties -> the
/// smaller label. Used by the per-vertex resolution `ReduceByKey<Min>`.
#[inline(always)]
pub fn pack_energy_label(energy: f32, label: u8) -> u64 {
    ((sortable_f32(energy) as u64) << 32) | label as u64
}

/// Unpack the label from a packed (energy, label) value.
#[inline(always)]
pub fn unpack_label(packed: u64) -> u8 {
    (packed & 1) as u8
}

/// Unpack the energy.
#[inline(always)]
pub fn unpack_energy(packed: u64) -> f32 {
    let s = (packed >> 32) as u32;
    let bits = if s & 0x8000_0000 != 0 { s & 0x7fff_ffff } else { !s };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> Params {
        Params { mu: [40.0, 180.0], sigma: [12.0, 30.0], beta: 0.5 }
    }

    #[test]
    fn closer_mean_wins_without_smoothness() {
        let p = Params { beta: 0.0, ..p() };
        let (_, l) = energy_min(45.0, 0.0, 0.0, 2.0, &p);
        assert_eq!(l, 0);
        let (_, l) = energy_min(190.0, 0.0, 0.0, 2.0, &p);
        assert_eq!(l, 1);
    }

    #[test]
    fn smoothness_pulls_toward_majority() {
        // y exactly between means & equal sigmas -> data tie; a hood full
        // of 1-labels must pull the vertex to 1.
        let p = Params { mu: [100.0, 140.0], sigma: [20.0, 20.0], beta: 1.0 };
        let (_, l) = energy_min(120.0, 0.0, 10.0, 11.0, &p);
        assert_eq!(l, 1);
        let (_, l) = energy_min(120.0, 0.0, 0.0, 11.0, &p);
        assert_eq!(l, 0);
    }

    #[test]
    fn tie_prefers_label_zero() {
        let p = Params { mu: [100.0, 100.0], sigma: [10.0, 10.0], beta: 0.0 };
        let (_, l) = energy_min(55.0, 1.0, 3.0, 8.0, &p);
        assert_eq!(l, 0);
    }

    #[test]
    fn sortable_preserves_order() {
        let xs = [-1000.0f32, -1.5, -0.0, 0.0, 1e-20, 3.14, 2e8];
        for w in xs.windows(2) {
            assert!(sortable_f32(w[0]) <= sortable_f32(w[1]),
                    "{} vs {}", w[0], w[1]);
        }
    }

    #[test]
    fn pack_roundtrip_and_min_semantics() {
        let a = pack_energy_label(1.5, 1);
        let b = pack_energy_label(2.5, 0);
        assert!(a < b, "lower energy wins regardless of label");
        let c = pack_energy_label(1.5, 0);
        assert!(c < a, "equal energy -> smaller label wins");
        assert_eq!(unpack_label(a), 1);
        assert_eq!(unpack_energy(a), 1.5);
        assert_eq!(unpack_energy(pack_energy_label(-3.25, 0)), -3.25);
    }

    #[test]
    fn energy_matches_manual_computation() {
        let p = p();
        let (e0, e1) = energy_pair(100.0, 1.0, 3.0, 5.0, &p);
        let want0 = (100.0f32 - 40.0).powi(2) / (2.0 * 144.0)
            + 12.0f32.ln()
            + 0.5 * (3.0 - 1.0);
        let want1 = (100.0f32 - 180.0).powi(2) / (2.0 * 900.0)
            + 30.0f32.ln()
            + 0.5 * ((5.0 - 3.0) - 0.0);
        assert!((e0 - want0).abs() < 1e-5);
        assert!((e1 - want1).abs() < 1e-5);
    }
}
