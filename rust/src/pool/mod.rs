//! TBB-style shared-memory thread pool substrate.
//!
//! The paper's DPPs run on top of Intel TBB (CPU back end): a linear
//! array is recursively split into chunks, each thread works on a
//! grain-sized chunk, and idle threads steal work (§4.1.3). The offline
//! registry has no `rayon`/`tokio`, so this module reimplements that
//! model from scratch on `std::thread`:
//!
//! * each worker owns a contiguous index *range* stored in a packed
//!   atomic (`start:u32 | end:u32`);
//! * the owner pops grain-sized chunks from the **front** of its range;
//! * an idle worker steals the **back half** of the largest victim
//!   range (classic range stealing — the contiguous analog of deque
//!   stealing, preserving locality for the victim);
//! * the submitting thread participates as worker 0, so a 1-thread pool
//!   runs fully inline.
//!
//! Pools are cheap to keep around; benches build one pool per
//! concurrency level and reuse it across runs.
//!
//! Besides the fork-join [`Pool::parallel_for`], the pool offers a
//! *persistent region* ([`Pool::region`]): all workers enter one
//! closure together and separate their phases with a [`PhaseBarrier`]
//! instead of paying a fork-join per pass — the substrate the fused
//! [`crate::dpp::Pipeline`] executes on.

use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Default chunk size (elements) a worker claims at a time. Matches the
/// DPP engine's notion of a "task" (§4.1.3); ablation
/// `benches/ablation_grain.rs` sweeps this.
pub const DEFAULT_GRAIN: usize = 4096;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Packed(u64);

impl Packed {
    #[inline]
    fn new(start: u32, end: u32) -> Self {
        Packed(((start as u64) << 32) | end as u64)
    }
    #[inline]
    fn start(self) -> u32 {
        (self.0 >> 32) as u32
    }
    #[inline]
    fn end(self) -> u32 {
        self.0 as u32
    }
}

/// State of one `parallel_for` invocation, shared with workers.
struct JobState {
    /// Type-erased `f(start, end)` with caller-guaranteed lifetime: the
    /// submitter does not return until `processed == n`, and calls only
    /// happen on successfully popped chunks.
    f: *const (dyn Fn(usize, usize) + Sync),
    n: usize,
    grain: usize,
    ranges: Vec<AtomicU64>,
    processed: AtomicUsize,
}

unsafe impl Send for JobState {}
unsafe impl Sync for JobState {}

impl JobState {
    /// Pop a grain-sized chunk from the front of `ranges[w]`.
    fn pop_front(&self, w: usize) -> Option<Range<usize>> {
        let slot = &self.ranges[w];
        loop {
            let cur = Packed(slot.load(Ordering::Acquire));
            let (s, e) = (cur.start(), cur.end());
            if s >= e {
                return None;
            }
            let ns = (s as usize + self.grain).min(e as usize) as u32;
            let new = Packed::new(ns, e);
            if slot
                .compare_exchange_weak(cur.0, new.0, Ordering::AcqRel,
                                       Ordering::Relaxed)
                .is_ok()
            {
                return Some(s as usize..ns as usize);
            }
        }
    }

    /// Steal the back half of the largest victim range; installs the
    /// loot as worker `w`'s new range. Returns false if nothing to steal.
    fn steal(&self, w: usize) -> bool {
        // Pick the victim with the most remaining work (cheap scan — the
        // pool is small).
        let mut best: Option<(usize, Packed)> = None;
        for (v, slot) in self.ranges.iter().enumerate() {
            if v == w {
                continue;
            }
            let cur = Packed(slot.load(Ordering::Acquire));
            let rem = cur.end().saturating_sub(cur.start());
            if rem as usize > self.grain {
                match best {
                    Some((_, b))
                        if b.end() - b.start() >= rem => {}
                    _ => best = Some((v, cur)),
                }
            }
        }
        let (v, cur) = match best {
            Some(x) => x,
            None => return false,
        };
        let (s, e) = (cur.start(), cur.end());
        let mid = e - (e - s) / 2;
        let shrunk = Packed::new(s, mid);
        if self.ranges[v]
            .compare_exchange(cur.0, shrunk.0, Ordering::AcqRel,
                              Ordering::Relaxed)
            .is_ok()
        {
            self.ranges[w].store(Packed::new(mid, e).0, Ordering::Release);
            true
        } else {
            false // lost the race; caller retries
        }
    }

    /// Work until the job is drained. `w` is this worker's slot.
    fn run(&self, w: usize) {
        let f = unsafe { &*self.f };
        loop {
            while let Some(r) = self.pop_front(w) {
                f(r.start, r.end);
                self.processed.fetch_add(r.len(), Ordering::AcqRel);
            }
            if self.processed.load(Ordering::Acquire) >= self.n {
                return;
            }
            if !self.steal(w) {
                if self.processed.load(Ordering::Acquire) >= self.n {
                    return;
                }
                std::thread::yield_now();
            }
        }
    }
}

struct Shared {
    job: Mutex<(u64, Option<Arc<JobState>>)>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// A fixed-size worker pool. `threads` includes the submitting thread.
pub struct Pool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
    submit: Mutex<()>,
}

impl Pool {
    /// Create a pool with `threads` total workers (>= 1). The calling
    /// thread acts as worker 0 during each `parallel_for`.
    pub fn new(threads: usize) -> Arc<Pool> {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            job: Mutex::new((0, None)),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let mut handles = Vec::new();
        for w in 1..threads {
            let sh = Arc::clone(&shared);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("dpp-worker-{w}"))
                    .spawn(move || worker_loop(sh, w))
                    .expect("spawn worker"),
            );
        }
        Arc::new(Pool { shared, handles, threads, submit: Mutex::new(()) })
    }

    /// Pool sized to the machine.
    pub fn with_default_threads() -> Arc<Pool> {
        Pool::new(available_threads())
    }

    /// Single-threaded pool (runs inline; used by the Serial backend
    /// tests to cross-check behaviour).
    pub fn serial() -> Arc<Pool> {
        Pool::new(1)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(start, end)` over disjoint chunks covering `0..n`.
    /// Blocks until every element has been processed.
    pub fn parallel_for<F>(&self, n: usize, grain: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let grain = grain.max(1);
        if self.threads == 1 || n <= grain {
            f(0, n);
            return;
        }
        assert!(n <= u32::MAX as usize, "range too large for packed atomics");

        let _guard = self.submit.lock().unwrap();
        // Even initial partition across workers.
        let per = n / self.threads;
        let rem = n % self.threads;
        let mut ranges = Vec::with_capacity(self.threads);
        let mut at = 0usize;
        for w in 0..self.threads {
            let len = per + usize::from(w < rem);
            ranges.push(AtomicU64::new(
                Packed::new(at as u32, (at + len) as u32).0,
            ));
            at += len;
        }
        // Erase the closure's lifetime: we guarantee below that no call
        // into `f` happens after this function returns (processed == n
        // before the job is detached, and calls only follow pops).
        let f_erased: &'static (dyn Fn(usize, usize) + Sync) = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize, usize) + Sync),
                &'static (dyn Fn(usize, usize) + Sync),
            >(&f)
        };
        let state = Arc::new(JobState {
            f: f_erased as *const _,
            n,
            grain,
            ranges,
            processed: AtomicUsize::new(0),
        });

        {
            let mut slot = self.shared.job.lock().unwrap();
            slot.0 += 1;
            slot.1 = Some(Arc::clone(&state));
            self.shared.cv.notify_all();
        }

        // Participate as worker 0; returns when processed == n.
        state.run(0);

        // Detach the job so late workers see nothing to do.
        let mut slot = self.shared.job.lock().unwrap();
        slot.1 = None;
    }

    /// Coarse task parallelism: `f(i)` for each `i in 0..tasks`, one
    /// task per chunk. This is the OpenMP-reference engine's
    /// `parallel for schedule(dynamic, 1)` analog.
    pub fn parallel_tasks<F>(&self, tasks: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.parallel_for(tasks, 1, |s, e| {
            for i in s..e {
                f(i);
            }
        });
    }
}

/// Sense-reversing spin barrier separating the *phases* of a
/// persistent parallel region ([`Pool::region`]).
///
/// `wait` blocks until every participant has arrived, then releases
/// them all into the next phase. Release/Acquire ordering on the
/// generation counter makes every write performed before a `wait`
/// visible to every participant after it — which is what lets pipeline
/// stages read what the previous stage wrote without a fork-join.
///
/// The barrier spins with [`std::thread::yield_now`] rather than
/// parking: phases in a DPP pipeline are microseconds apart, and the
/// whole point of the persistent region is to avoid the
/// condvar/fork-join latency of one [`Pool::parallel_for`] per stage.
pub struct PhaseBarrier {
    participants: usize,
    arrived: AtomicUsize,
    generation: AtomicUsize,
}

impl PhaseBarrier {
    /// Barrier for `participants` cooperating workers (>= 1).
    pub fn new(participants: usize) -> PhaseBarrier {
        PhaseBarrier {
            participants: participants.max(1),
            arrived: AtomicUsize::new(0),
            generation: AtomicUsize::new(0),
        }
    }

    pub fn participants(&self) -> usize {
        self.participants
    }

    /// Block until all participants reach the barrier. The last arrival
    /// resets the count and advances the generation, releasing the
    /// spinners; a single-participant barrier returns immediately.
    pub fn wait(&self) {
        if self.participants <= 1 {
            return;
        }
        let gen = self.generation.load(Ordering::Acquire);
        if self.arrived.fetch_add(1, Ordering::AcqRel) + 1
            == self.participants
        {
            // Reset BEFORE advancing the generation: a released worker
            // may reach the next barrier and increment `arrived` the
            // moment the generation moves.
            self.arrived.store(0, Ordering::Release);
            self.generation
                .store(gen.wrapping_add(1), Ordering::Release);
        } else {
            while self.generation.load(Ordering::Acquire) == gen {
                std::thread::yield_now();
            }
        }
    }
}

impl Pool {
    /// Persistent parallel region: run `f(worker, barrier)` once on
    /// every worker of the pool *concurrently*. Workers coordinate
    /// phases themselves through the shared [`PhaseBarrier`] instead of
    /// paying one fork-join per data-parallel pass — the substrate for
    /// [`crate::dpp::Pipeline`].
    ///
    /// Guarantees: exactly `threads()` invocations of `f`, each with a
    /// distinct `worker` in `0..threads()`, each on its own OS thread
    /// (worker 0 is the submitting thread), all live at the same time.
    /// This rides on [`Pool::parallel_for`] with `n == threads` and
    /// grain 1: the initial partition hands every worker exactly one
    /// index and the steal path never triggers (a 1-element range is
    /// never above the grain), so no worker can ever own two region
    /// slots — which would deadlock the barrier.
    ///
    /// `f` must NOT submit further work to this pool (the submit lock
    /// is held for the duration of the region).
    pub fn region<F>(&self, f: F)
    where
        F: Fn(usize, &PhaseBarrier) + Sync,
    {
        let barrier = PhaseBarrier::new(self.threads);
        self.parallel_for(self.threads, 1, |s, e| {
            for w in s..e {
                f(w, &barrier);
            }
        });
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, w: usize) {
    let mut seen_epoch = 0u64;
    loop {
        let state = {
            let mut slot = shared.job.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                if slot.0 != seen_epoch {
                    seen_epoch = slot.0;
                    if let Some(s) = slot.1.clone() {
                        break s;
                    }
                    // epoch advanced but job already detached — re-wait
                }
                slot = shared.cv.wait(slot).unwrap();
            }
        };
        state.run(w);
    }
}

/// Number of hardware threads (physical-ish; honours
/// `DPP_PMRF_THREADS` for pinning in benches).
pub fn available_threads() -> usize {
    if let Ok(v) = std::env::var("DPP_PMRF_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn covers_every_index_exactly_once() {
        for threads in [1, 2, 4, 8] {
            let pool = Pool::new(threads);
            let n = 100_000;
            let hits: Vec<AtomicU32> =
                (0..n).map(|_| AtomicU32::new(0)).collect();
            pool.parallel_for(n, 1000, |s, e| {
                for i in s..e {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn sum_matches_serial() {
        let pool = Pool::new(4);
        let n = 1_000_000usize;
        let total = AtomicUsize::new(0);
        pool.parallel_for(n, 4096, |s, e| {
            let local: usize = (s..e).sum();
            total.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), n * (n - 1) / 2);
    }

    #[test]
    fn reuse_across_jobs() {
        let pool = Pool::new(4);
        for round in 0..50 {
            let count = AtomicUsize::new(0);
            pool.parallel_for(997 + round, 64, |s, e| {
                count.fetch_add(e - s, Ordering::Relaxed);
            });
            assert_eq!(count.load(Ordering::Relaxed), 997 + round);
        }
    }

    #[test]
    fn empty_and_tiny() {
        let pool = Pool::new(4);
        pool.parallel_for(0, 16, |_, _| panic!("no work expected"));
        let count = AtomicUsize::new(0);
        pool.parallel_for(1, 16, |s, e| {
            count.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn tasks_visit_each_index() {
        let pool = Pool::new(3);
        let n = 257;
        let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        pool.parallel_tasks(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn region_runs_every_worker_once() {
        for threads in [1, 2, 4, 7] {
            let pool = Pool::new(threads);
            let hits: Vec<AtomicU32> =
                (0..threads).map(|_| AtomicU32::new(0)).collect();
            pool.region(|w, _| {
                hits[w].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn region_phases_stay_in_lockstep() {
        // Each worker bumps a per-phase counter, then barriers. If the
        // barrier failed to hold a phase, a worker would observe a
        // partial count from the next phase.
        let threads = 4;
        let pool = Pool::new(threads);
        let phases = 16;
        let counts: Vec<AtomicU32> =
            (0..phases).map(|_| AtomicU32::new(0)).collect();
        pool.region(|_, barrier| {
            for p in 0..phases {
                counts[p].fetch_add(1, Ordering::AcqRel);
                barrier.wait();
                // After the barrier, every participant must have
                // contributed to this phase.
                assert_eq!(
                    counts[p].load(Ordering::Acquire),
                    threads as u32,
                    "phase {p} released early"
                );
            }
        });
    }

    #[test]
    fn region_barrier_publishes_prior_phase_writes() {
        // Worker 0 writes in phase 0; everyone reads in phase 1.
        let threads = 4;
        let pool = Pool::new(threads);
        let cell = AtomicU32::new(0);
        pool.region(|w, barrier| {
            if w == 0 {
                cell.store(42, Ordering::Relaxed);
            }
            barrier.wait();
            assert_eq!(cell.load(Ordering::Relaxed), 42);
        });
    }

    #[test]
    fn pool_reusable_after_region() {
        let pool = Pool::new(3);
        pool.region(|_, b| b.wait());
        let count = AtomicUsize::new(0);
        pool.parallel_for(1000, 64, |s, e| {
            count.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn uneven_work_gets_stolen() {
        // Front-loaded cost: without stealing, worker 0 would finish far
        // later. We only assert correctness here (timing asserted in
        // benches), but with a tiny grain the steal path is exercised.
        let pool = Pool::new(4);
        let n = 10_000;
        let total = AtomicUsize::new(0);
        pool.parallel_for(n, 8, |s, e| {
            for i in s..e {
                if i < 100 {
                    std::thread::sleep(std::time::Duration::from_micros(10));
                }
                total.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), n);
    }
}
