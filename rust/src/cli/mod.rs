//! CLI argument parser substrate (clap stand-in).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with generated `--help` text. Only what the
//! launcher needs — no derive magic.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Declared option.
#[derive(Debug, Clone)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// A (sub)command spec.
#[derive(Debug, Clone, Default)]
pub struct Spec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<Opt>,
    pub positionals: Vec<(&'static str, &'static str)>,
}

impl Spec {
    pub fn new(name: &'static str, about: &'static str) -> Spec {
        Spec { name, about, opts: Vec::new(), positionals: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Spec {
        self.opts.push(Opt { name, help, takes_value: false, default: None });
        self
    }

    pub fn opt(
        mut self,
        name: &'static str,
        help: &'static str,
        default: Option<&'static str>,
    ) -> Spec {
        self.opts.push(Opt { name, help, takes_value: true, default });
        self
    }

    pub fn positional(mut self, name: &'static str, help: &'static str)
        -> Spec {
        self.positionals.push((name, help));
        self
    }

    fn find(&self, name: &str) -> Option<&Opt> {
        self.opts.iter().find(|o| o.name == name)
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {}", self.name,
                              self.about, self.name);
        if !self.opts.is_empty() {
            out.push_str(" [OPTIONS]");
        }
        for (p, _) in &self.positionals {
            out.push_str(&format!(" <{p}>"));
        }
        out.push('\n');
        if !self.positionals.is_empty() {
            out.push_str("\nARGS:\n");
            for (p, h) in &self.positionals {
                out.push_str(&format!("  <{p}>  {h}\n"));
            }
        }
        if !self.opts.is_empty() {
            out.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let v = if o.takes_value { " <value>" } else { "" };
                let d = o
                    .default
                    .map(|d| format!(" [default: {d}]"))
                    .unwrap_or_default();
                out.push_str(&format!("  --{}{v}  {}{d}\n", o.name, o.help));
            }
        }
        out
    }

    /// Parse a raw argument list against this spec.
    pub fn parse(&self, args: &[String]) -> Result<Matches, CliError> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positionals: Vec<String> = Vec::new();
        let mut it = args.iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(CliError(self.usage()));
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let opt = self.find(name).ok_or_else(|| {
                    CliError(format!("unknown option --{name}\n\n{}",
                                     self.usage()))
                })?;
                if opt.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| {
                                CliError(format!("--{name} needs a value"))
                            })?
                            .clone(),
                    };
                    values.insert(name.to_string(), v);
                } else {
                    if inline.is_some() {
                        return Err(CliError(format!(
                            "--{name} takes no value")));
                    }
                    flags.push(name.to_string());
                }
            } else {
                positionals.push(arg.clone());
            }
        }
        if positionals.len() > self.positionals.len() {
            return Err(CliError(format!(
                "unexpected argument `{}`\n\n{}",
                positionals[self.positionals.len()],
                self.usage()
            )));
        }
        for o in &self.opts {
            if let Some(d) = o.default {
                values.entry(o.name.to_string()).or_insert(d.to_string());
            }
        }
        Ok(Matches { values, flags, positionals })
    }
}

/// Parse result.
#[derive(Debug, Clone, Default)]
pub struct Matches {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Matches {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str)
        -> Result<Option<T>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s.parse::<T>().map(Some).map_err(|_| {
                CliError(format!("--{name}: cannot parse `{s}`"))
            }),
        }
    }

    /// Parse with a required default already injected by the spec.
    pub fn req<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError> {
        self.get_parse(name)?.ok_or_else(|| {
            CliError(format!("missing required option --{name}"))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Spec {
        Spec::new("seg", "segment an image")
            .opt("threads", "worker threads", Some("4"))
            .opt("out", "output path", None)
            .flag("verbose", "chatty logs")
            .positional("input", "input volume")
    }

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_forms() {
        let m = spec()
            .parse(&args(&["--threads=8", "vol.raw", "--verbose",
                           "--out", "seg.raw"]))
            .unwrap();
        assert_eq!(m.req::<usize>("threads").unwrap(), 8);
        assert_eq!(m.get("out"), Some("seg.raw"));
        assert!(m.flag("verbose"));
        assert_eq!(m.positional(0), Some("vol.raw"));
    }

    #[test]
    fn defaults_apply() {
        let m = spec().parse(&args(&[])).unwrap();
        assert_eq!(m.req::<usize>("threads").unwrap(), 4);
        assert_eq!(m.get("out"), None);
    }

    #[test]
    fn rejects_unknown_and_extra() {
        assert!(spec().parse(&args(&["--nope"])).is_err());
        assert!(spec().parse(&args(&["a", "b"])).is_err());
        assert!(spec().parse(&args(&["--verbose=1"])).is_err());
    }

    #[test]
    fn help_is_an_error_with_usage() {
        let e = spec().parse(&args(&["--help"])).unwrap_err();
        assert!(e.0.contains("USAGE"));
        assert!(e.0.contains("--threads"));
    }

    #[test]
    fn bad_parse_reports_option() {
        let e = spec()
            .parse(&args(&["--threads", "lots"]))
            .unwrap()
            .req::<usize>("threads")
            .unwrap_err();
        assert!(e.0.contains("threads"));
    }
}
